//! Sharded in-memory sketch store over contiguous arenas, with optional
//! crash-safe persistence.
//!
//! Each shard owns a [`SketchMatrix`]: one row-major `u64` word arena per
//! shard (plus a cached per-row Hamming weight), so a shard scan walks a
//! single allocation instead of chasing one heap pointer per sketch.
//! Placement is least-loaded by *reserved* point counts: each batch picks
//! the shard with the smallest atomic counter and bumps it by the batch
//! size before placing a single row. The reservation is visible to every
//! later scan immediately, so a single client's inserts spread evenly
//! across variable-size batches and concurrent batchers cannot pile onto
//! one shard the way the old read-then-write scan (which only observed a
//! shard's size after its batch fully landed) allowed.
//!
//! A global id index (`id → (shard, row)`, dense because ids are assigned
//! by a monotone counter and never reused) makes [`ShardedStore::get`] and
//! [`ShardedStore::pair_stats`] O(1) instead of a linear scan over every
//! shard.
//!
//! Persistence (optional, see [`crate::persist`]): a store opened with
//! [`ShardedStore::open_durable`] recovers its pre-crash state (newest
//! snapshot + WAL tail, per-shard LSH indexes bulk-rebuilt via the
//! existing [`LshIndex::rebuild`] path) and then appends a WAL record for
//! every arena mutation *under the same shard write lock that performs
//! it* — so a shard's log order is exactly its arena mutation order, and
//! per-shard replay needs no cross-shard coordination. Each `insert_batch`
//! / rebalance pass commits its WAL batch before returning, which is
//! before the batcher acknowledges the insert: with `fsync = always`,
//! acknowledged inserts survive `kill -9`.
//!
//! Mutation (delete / upsert / TTL): the corpus is *not* append-only. A
//! delete swap-removes the row from its shard arena (O(1): the trailing
//! row drops into the hole), mirrors that move into the shard's LSH
//! index and the global id index under the same write locks, and logs a
//! `Delete` frame; the id itself is never reused. An upsert overwrites
//! the row in place when the id is resident (same shard, same row — an
//! `Upsert` frame) and re-inserts under the original id via least-loaded
//! placement when the id was previously deleted. Every row carries an
//! optional absolute TTL deadline (unix millis, 0 = none), persisted in
//! both WAL frames and snapshots and carried across rebalance moves;
//! [`ShardedStore::sweep_expired`] turns expired rows into ordinary
//! deletes (the serving layer runs it on the primary only — followers
//! see the resulting `Delete` frames on the replication stream and never
//! sweep themselves, so primary and replica stay bit-identical). Frames
//! a mutation obsoletes (the delete itself plus the insert it
//! tombstones; an upsert's overwritten predecessor) are reported to the
//! persist layer's dead-frame counter, whose threshold folds WAL
//! compaction into the next snapshot rotation.
//!
//! Scan execution: every serving-path scatter runs on the store's
//! persistent [`ShardExecutor`] — one long-lived worker thread per shard
//! behind a bounded work queue ([`ShardedStore::scatter_gather`]), spawned
//! once at store construction instead of per request. The old per-request
//! scoped-spawn scatter survives only as [`ShardedStore::par_map_shards`],
//! kept as the comparison baseline for `bench_router` and as a
//! scoped-borrow convenience for tests; no serving path uses it.
//!
//! Group commit (see [`crate::persist`]): when the persist config sets a
//! commit window (and `fsync = always` — the policy with a per-commit
//! fsync to amortise), `insert_batch` appends its WAL frames under the shard
//! lock as always but leaves the fsync to the group-commit thread, which
//! coalesces every batch that lands in the same window into one
//! fsync per touched shard. The ack (the batch call returning, and with
//! it the batcher's client reply) still waits for the window's commit —
//! "acked ⇒ survives kill -9" is preserved — and a commit *failure* now
//! surfaces to the caller through [`ShardedStore::try_insert_batch`]
//! instead of being logged and silently acked.
//!
//! Lock order (deadlock freedom): the id index is always acquired *before*
//! any shard lock, multiple shard locks are always acquired in ascending
//! shard order, and the per-shard WAL mutexes are strict leaves acquired
//! after their shard's lock (in ascending order when more than one is
//! held). Scan paths (`map_shards`/`par_map_shards`/executor workers)
//! touch only shard locks, and the group-commit thread touches only WAL
//! mutexes.
//!
//! Poison recovery: every lock acquisition in this file routes through
//! [`read_l`]/[`write_l`], which recover a poisoned guard instead of
//! unwrapping. A panicking worker used to brick the whole coordinator —
//! one poisoned shard `RwLock` turned every subsequent request into a
//! panic. Sketch arenas are plain `u64` rows plus cached weights, and
//! every mutation path orders its writes so a panic mid-batch leaves
//! `rows`/`ids` consistent for all fully-placed elements (the failing
//! element contributes nothing and its id simply stays `VACANT`), so a
//! recovered guard always observes a readable shard.

use super::executor::{ExecutorConfig, ShardExecutor};
use crate::index::{IndexConfig, LshIndex};
use crate::obs::{self, log as obs_log, Stages};
use crate::persist::wal::WalRecord;
use crate::persist::{Fingerprint, PersistConfig, PersistCounters, Persistence, RecoveryReport};
use crate::sketch::bitvec::{and_count_words, popcount_words};
use crate::sketch::{BitVec, SketchMatrix};
use anyhow::Context;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Instant;

/// `(shard, row)` index entry; `VACANT` marks an id whose batch is still
/// being placed (visible only to concurrent readers mid-insert), or whose
/// placement was aborted by a panic.
type Slot = (u32, u32);
const VACANT: Slot = (u32::MAX, u32::MAX);

/// Poison-recovering read lock (see the module docs).
fn read_l<T>(lock: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    lock.read().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-recovering write lock (see the module docs).
fn write_l<T>(lock: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    lock.write().unwrap_or_else(PoisonError::into_inner)
}

pub struct Shard {
    pub ids: Vec<usize>,
    pub rows: SketchMatrix,
    /// Per-row absolute TTL deadline (unix millis, 0 = no expiry),
    /// parallel to `ids`/`rows` and maintained by every mutation path
    /// under the shard write lock.
    pub expiry: Vec<u64>,
    /// Optional per-shard LSH candidate index over `rows` (None when the
    /// store was built without indexing). Guarded by the same shard lock
    /// as the arena, so index and rows can never be observed out of step.
    pub index: Option<LshIndex>,
}

pub struct ShardedStore {
    /// Shard locks are `Arc`-shared with the executor's worker threads
    /// (each worker owns a clone of its shard's lock), so the executor
    /// needs no back reference to the store.
    shards: Vec<Arc<RwLock<Shard>>>,
    /// Dense id → (shard, row). Guarded by its own lock; see the module
    /// docs for the global lock order.
    index: RwLock<Vec<Slot>>,
    next_id: AtomicUsize,
    /// Reserved per-shard point counts (see module docs): bumped at
    /// placement time, before the rows land, and kept exact by
    /// `rebalance`. Placement heuristic only — `shard_sizes` is truth.
    reserved: Vec<AtomicUsize>,
    sketch_dim: usize,
    /// Next rebalance move id: every `MoveOut`/`MoveIn` pair is stamped
    /// with one fresh id so a replication puller can recognise the pair
    /// and order the destination's apply before the source's. Seeded
    /// past the highest replayed move id on recovery.
    move_id: AtomicU64,
    /// WAL + snapshot machinery; `None` for a purely in-memory store.
    persist: Option<Persistence>,
    /// Persistent per-shard scan workers; all serving scatters run here.
    executor: ShardExecutor,
    /// Write-path stage histograms (placement / WAL / fsync-wait),
    /// attached once by the server after `Metrics` exists. Unset (bench
    /// and library callers) means stage timing is compiled out of the
    /// path save for one pointer load.
    stage_obs: OnceLock<Arc<Stages>>,
}

/// The durability half of a split insert: produced by
/// [`ShardedStore::begin_insert_batch`] (rows placed, frames appended,
/// commit started), settled by [`ShardedStore::finish_insert_batch`]
/// (window waited, traffic accounted, auto-snapshot probed). Letting the
/// two run on different threads is what overlaps the batcher's sketching
/// with the in-flight fsync window.
#[must_use = "an unsettled insert ticket skips the durability wait and the ack gate"]
pub struct InsertTicket {
    /// Shard the batch landed on.
    target: usize,
    /// Rows placed (0 = empty batch, nothing to settle).
    records: u64,
    /// WAL bytes appended for those rows.
    wal_bytes: u64,
    /// Group-commit window still owed a wait, when one was registered.
    window_epoch: Option<u64>,
    /// Synchronous-commit failure already observed at begin time.
    sync_err: Option<anyhow::Error>,
}

impl InsertTicket {
    fn empty() -> InsertTicket {
        InsertTicket {
            target: 0,
            records: 0,
            wal_bytes: 0,
            window_epoch: None,
            sync_err: None,
        }
    }
}

/// One corpus mutation, as submitted to
/// [`ShardedStore::begin_mutation_batch`] — the store-level shape of the
/// wire's `insert`/`delete`/`upsert` ops. `deadline` is an absolute TTL
/// expiry in unix milliseconds, `0` for no expiry.
pub enum MutationOp {
    Insert { sketch: BitVec, deadline: u64 },
    Delete { id: usize },
    Upsert { id: usize, sketch: BitVec, deadline: u64 },
}

/// Per-op outcome of a mutation batch, in submission order. A `Failed`
/// op (unknown id) affects only itself — the rest of the batch still
/// applies.
#[derive(Debug, PartialEq, Eq)]
pub enum MutationResult {
    Inserted { id: usize },
    Deleted { id: usize },
    Upserted { id: usize },
    Failed { error: String },
}

/// The durability half of a mutation batch — the multi-shard analogue of
/// [`InsertTicket`] (mixed ops fan out: each op lands on its id's shard,
/// or the least-loaded one, so one batch can touch several WALs).
/// Produced by [`ShardedStore::begin_mutation_batch`], settled by
/// [`ShardedStore::finish_mutation_batch`].
#[must_use = "an unsettled mutation ticket skips the durability wait and the ack gate"]
pub struct MutationTicket {
    /// Open group-commit windows still owed a wait: `(shard, epoch)`.
    windows: Vec<(usize, u64)>,
    /// WAL frames appended across all touched shards.
    records: u64,
    /// WAL bytes appended for those frames.
    wal_bytes: u64,
    /// First synchronous-commit failure observed at begin time.
    sync_err: Option<anyhow::Error>,
}

impl ShardedStore {
    pub fn new(num_shards: usize, sketch_dim: usize) -> Self {
        Self::build(num_shards, sketch_dim, None, &ExecutorConfig::default())
    }

    /// A store whose shards each carry an [`LshIndex`] (unless the config's
    /// mode is `Off`). All shards derive their band samples from the same
    /// `seed`, so a rebuilt or rebalanced shard buckets rows exactly like a
    /// freshly grown one.
    pub fn with_index(
        num_shards: usize,
        sketch_dim: usize,
        cfg: &IndexConfig,
        seed: u64,
    ) -> Self {
        Self::with_runtime(num_shards, sketch_dim, cfg, seed, &ExecutorConfig::default())
    }

    /// Full in-memory constructor: index config plus executor knobs
    /// (queue bound, shared counters) — what the coordinator uses so the
    /// `executor_*` stats fields track this store's workers.
    pub fn with_runtime(
        num_shards: usize,
        sketch_dim: usize,
        cfg: &IndexConfig,
        seed: u64,
        exec: &ExecutorConfig,
    ) -> Self {
        let index = cfg.enabled().then(|| (*cfg, seed));
        Self::build(num_shards, sketch_dim, index, exec)
    }

    fn build(
        num_shards: usize,
        sketch_dim: usize,
        index: Option<(IndexConfig, u64)>,
        exec: &ExecutorConfig,
    ) -> Self {
        let shards: Vec<Arc<RwLock<Shard>>> = (0..num_shards.max(1))
            .map(|_| {
                Arc::new(RwLock::new(Shard {
                    ids: Vec::new(),
                    rows: SketchMatrix::new(sketch_dim),
                    expiry: Vec::new(),
                    index: index
                        .as_ref()
                        .map(|(cfg, seed)| LshIndex::new(cfg, sketch_dim, *seed)),
                }))
            })
            .collect();
        let executor = ShardExecutor::start(&shards, exec);
        Self {
            shards,
            index: RwLock::new(Vec::new()),
            next_id: AtomicUsize::new(0),
            reserved: (0..num_shards.max(1)).map(|_| AtomicUsize::new(0)).collect(),
            sketch_dim,
            move_id: AtomicU64::new(1),
            persist: None,
            executor,
            stage_obs: OnceLock::new(),
        }
    }

    /// Open a durable store: recover `persist_cfg.data_dir` (hard error on
    /// a configuration-fingerprint mismatch — sketches persisted under a
    /// different `input_dim`/`num_categories`/`sketch_dim`/`seed` mapping
    /// or shard layout would corrupt every Cham estimate), bulk-rebuild
    /// the per-shard LSH indexes over the recovered arenas, and keep
    /// WAL-logging every mutation from here on. `counters` is shared with
    /// `coordinator::Metrics` so the `persist_*` stats fields track this
    /// store's traffic; likewise `exec.counters` for the `executor_*`
    /// fields.
    pub fn open_durable(
        fingerprint: Fingerprint,
        index_cfg: &IndexConfig,
        persist_cfg: &PersistConfig,
        counters: Arc<PersistCounters>,
        exec: &ExecutorConfig,
    ) -> anyhow::Result<(Self, RecoveryReport)> {
        let fingerprint = Fingerprint {
            num_shards: fingerprint.num_shards.max(1),
            ..fingerprint
        };
        let (sketch_dim, seed) = (fingerprint.sketch_dim, fingerprint.seed);
        let (persistence, parts, report) =
            Persistence::open(persist_cfg, fingerprint, counters)?;
        let index_enabled = index_cfg.enabled();
        let mut id_index: Vec<Slot> = Vec::new();
        let mut next_id = 0usize;
        let mut reserved = Vec::with_capacity(parts.len());
        let mut shards = Vec::with_capacity(parts.len());
        for (si, part) in parts.into_iter().enumerate() {
            let mut lsh = index_enabled.then(|| LshIndex::new(index_cfg, sketch_dim, seed));
            if let Some(ix) = lsh.as_mut() {
                // bulk reconstruction — the recovery role LshIndex::rebuild
                // exists for; incremental maintenance resumes afterwards
                ix.rebuild(&part.rows);
            }
            for (row, &id) in part.ids.iter().enumerate() {
                if id_index.len() <= id {
                    id_index.resize(id + 1, VACANT);
                }
                id_index[id] = (si as u32, row as u32);
                next_id = next_id.max(id + 1);
            }
            reserved.push(AtomicUsize::new(part.ids.len()));
            shards.push(Arc::new(RwLock::new(Shard {
                ids: part.ids,
                rows: part.rows,
                expiry: part.expiry,
                index: lsh,
            })));
        }
        let executor = ShardExecutor::start(&shards, exec);
        Ok((
            Self {
                shards,
                index: RwLock::new(id_index),
                next_id: AtomicUsize::new(next_id),
                reserved,
                sketch_dim,
                move_id: AtomicU64::new(report.max_move_id + 1),
                persist: Some(persistence),
                executor,
                stage_obs: OnceLock::new(),
            },
            report,
        ))
    }

    /// Attach the per-stage histogram set (idempotent — first caller
    /// wins). The server calls this right after building `Metrics`, so
    /// the placement / WAL / fsync-wait stages of every write land in
    /// the same `Stages` the batcher and router record into.
    pub fn attach_stages(&self, stages: Arc<Stages>) {
        let _ = self.stage_obs.set(stages);
    }

    #[inline]
    fn stages(&self) -> Option<&Arc<Stages>> {
        self.stage_obs.get()
    }

    /// The persistence handle, when this store is durable.
    pub fn persistence(&self) -> Option<&Persistence> {
        self.persist.as_ref()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn sketch_dim(&self) -> usize {
        self.sketch_dim
    }

    /// The id-space high-water mark: the number of ids ever assigned.
    /// Deletes do not shrink it (ids are never reused) — see
    /// [`ShardedStore::live_len`] for current occupancy.
    pub fn len(&self) -> usize {
        self.next_id.load(Ordering::Relaxed)
    }

    /// Number of rows currently resident: the id space minus deletions.
    pub fn live_len(&self) -> usize {
        self.shard_sizes().iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a batch of sketches; returns their assigned global ids. A
    /// durability (WAL commit) failure is logged but the ids are still
    /// returned — callers that must surface durability errors (the
    /// batcher's ack path) use [`ShardedStore::try_insert_batch`].
    pub fn insert_batch(&self, sketches: Vec<BitVec>) -> Vec<usize> {
        let (ids, commit_err) = self.insert_batch_inner(sketches);
        if let Some(e) = commit_err {
            obs_log::error(
                "store",
                "wal_commit_failed",
                &[
                    ("detail", obs_log::V::s("rows are in memory but NOT durable")),
                    ("error", obs_log::V::s(format!("{e:#}"))),
                ],
            );
            crate::obs::journal::record(
                "store",
                "wal_commit_failed",
                &[("error", obs_log::V::s(format!("{e:#}")))],
            );
        }
        ids
    }

    /// As [`ShardedStore::insert_batch`], but a WAL commit failure is an
    /// `Err` instead of a log line: the rows were placed in memory (and
    /// will be scannable until the process dies) but the durability
    /// contract was not met, so the caller must *not* acknowledge the
    /// insert as durable. The batcher routes this error to the waiting
    /// client as an insert error on the wire.
    pub fn try_insert_batch(&self, sketches: Vec<BitVec>) -> anyhow::Result<Vec<usize>> {
        let (ids, commit_err) = self.insert_batch_inner(sketches);
        match commit_err {
            None => Ok(ids),
            Some(e) => Err(e.context(
                "insert placed in memory but its WAL commit failed — not acknowledged as durable",
            )),
        }
    }

    /// Insert a batch of sketches; returns their assigned global ids plus
    /// any WAL commit error — [`ShardedStore::begin_insert_batch`]
    /// followed inline by [`ShardedStore::finish_insert_batch`].
    fn insert_batch_inner(&self, sketches: Vec<BitVec>) -> (Vec<usize>, Option<anyhow::Error>) {
        let (ids, ticket) = self.begin_insert_batch(sketches);
        (ids, self.finish_insert_batch(ticket).err())
    }

    /// Placement half of a pipelined insert: place the rows in memory,
    /// append their WAL frames, and *start* the commit — synchronously
    /// (the error lands in the ticket) when no commit window is
    /// configured, or by registering in the open group-commit window
    /// without waiting for it. The returned ticket must be settled with
    /// [`ShardedStore::finish_insert_batch`] before the batch may be
    /// acknowledged; splitting the two lets the batcher sketch batch N+1
    /// while batch N's fsync window is in flight (the ack-wait moves to a
    /// completion thread, see [`crate::coordinator::batcher`]).
    ///
    /// The batch lands on the shard with the fewest *reserved* points,
    /// and the batch size is reserved before any row is placed — so
    /// variable-size batches stay point-balanced (not merely
    /// batch-count-balanced) and concurrent batchers steer away from each
    /// other immediately instead of all observing the same stale minimum.
    pub fn begin_insert_batch(&self, sketches: Vec<BitVec>) -> (Vec<usize>, InsertTicket) {
        let k = sketches.len();
        if k == 0 {
            return (Vec::new(), InsertTicket::empty());
        }
        let start = self.next_id.fetch_add(k, Ordering::Relaxed);
        let ids: Vec<usize> = (start..start + k).collect();
        let target = self
            .reserved
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.reserved[target].fetch_add(k, Ordering::Relaxed);
        let mut wal_bytes = 0u64;
        // The WAL guard outlives the index/shard locks below: records are
        // appended (buffered) under the shard write lock so log order is
        // arena order, but the commit — an fdatasync under `fsync =
        // always` — runs after both locks are released. On the
        // synchronous path it runs under this very guard, so a snapshot
        // rotation cannot cut between append and commit. On the
        // group-commit path the guard is dropped before the committer
        // thread flushes, so a rotation CAN interleave — safely, because
        // `write_snapshot` commits every writer's pending frames (under
        // all WAL guards) before cutting the generation, and the window's
        // later commit on the fresh segment is then a no-op. Either way
        // disk latency never blocks readers or other shards' inserts, and
        // the ack (the ticket settling in `finish_insert_batch`) happens
        // after the commit.
        // (Readers can observe rows whose batch is not yet committed —
        // read-uncommitted for queries, commit-before-ack for writers.)
        let place_start = Instant::now();
        let mut wal = {
            let mut index = write_l(&self.index);
            if index.len() < start + k {
                index.resize(start + k, VACANT);
            }
            let mut shard = write_l(&self.shards[target]);
            let mut wal = self.persist.as_ref().map(|p| p.wal_guard(target));
            for (offset, sketch) in sketches.iter().enumerate() {
                let row = shard.rows.len() as u32;
                // Panic-safety ordering: the arena push validates (and can
                // panic) before mutating anything, so a bad element leaves
                // rows == ids for every fully-placed element and its own id
                // VACANT — a recovered-from-poison shard stays readable.
                shard.rows.push(sketch);
                shard.ids.push(start + offset);
                shard.expiry.push(0);
                // mirror the arena append into the LSH index (same write lock)
                if let Some(ix) = shard.index.as_mut() {
                    ix.insert(row as usize, sketch.words());
                }
                if let Some(w) = wal.as_deref_mut() {
                    // appends only buffer (infallible); I/O errors surface
                    // at the commit below
                    wal_bytes += w.append_insert((start + offset) as u64, sketch.words()) as u64;
                }
                index[start + offset] = (target as u32, row);
            }
            wal
        };
        if let Some(st) = self.stages() {
            st.write_place.record_us(obs::elapsed_us(place_start));
        }
        let wal_start = Instant::now();
        let mut ticket = InsertTicket {
            target,
            records: k as u64,
            wal_bytes,
            window_epoch: None,
            sync_err: None,
        };
        if let Some(p) = &self.persist {
            if p.group_commit_enabled() {
                // Group commit: the frames stay buffered in the writer.
                // Release the WAL mutex FIRST (the committer needs it to
                // flush this shard), then register in the open window —
                // the wait for that window's flush is the ticket's, so
                // the ack still happens after the commit, just off this
                // thread when the caller pipelines.
                drop(wal);
                ticket.window_epoch = Some(p.group_commit_register(target));
            } else {
                if let Some(w) = wal.as_deref_mut() {
                    if let Err(e) = w.commit() {
                        let e = anyhow::Error::new(e);
                        ticket.sync_err = Some(e.context(format!("WAL commit for shard {target}")));
                    }
                }
                drop(wal);
            }
        } else {
            drop(wal);
        }
        if let Some(st) = self.stages() {
            st.write_wal.record_us(obs::elapsed_us(wal_start));
        }
        (ids, ticket)
    }

    /// Settle a [`ShardedStore::begin_insert_batch`] ticket: wait for the
    /// batch's commit window (when one was registered), account the WAL
    /// traffic, and run the auto-snapshot trigger. `Err` means the rows
    /// are in memory but the durability contract was not met — the caller
    /// must not acknowledge the batch as durable. Must be called with no
    /// store locks held (a triggered auto-snapshot takes them all).
    pub fn finish_insert_batch(&self, ticket: InsertTicket) -> anyhow::Result<()> {
        let InsertTicket {
            target,
            records,
            wal_bytes,
            window_epoch,
            sync_err,
        } = ticket;
        if records == 0 {
            return Ok(());
        }
        let mut commit_err = sync_err;
        if let Some(p) = &self.persist {
            if let Some(epoch) = window_epoch {
                let fsync_start = Instant::now();
                commit_err = p
                    .group_commit_wait_epoch(target, epoch)
                    .err()
                    .map(|msg| anyhow::anyhow!("group commit for shard {target}: {msg}"));
                if let Some(st) = self.stages() {
                    st.write_fsync.record_us(obs::elapsed_us(fsync_start));
                }
            }
            p.note_appended(records, wal_bytes);
            self.maybe_auto_snapshot();
        }
        match commit_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Apply a batch of mixed mutations in submission order; returns one
    /// [`MutationResult`] per op plus the durability ticket. Each op
    /// acquires and releases its own id-index/shard/WAL locks (never two
    /// shard locks at once, so the global lock order holds trivially),
    /// and commits are started once per *touched shard* at the end —
    /// under a commit window the whole batch shares one group-commit
    /// registration per shard, mirroring the insert fast path. A per-op
    /// failure (unknown id) yields `Failed` for that op only.
    pub fn begin_mutation_batch(
        &self,
        ops: Vec<MutationOp>,
    ) -> (Vec<MutationResult>, MutationTicket) {
        let mut results = Vec::with_capacity(ops.len());
        let mut touched: Vec<usize> = Vec::new();
        let mut records = 0u64;
        let mut wal_bytes = 0u64;
        let place_start = Instant::now();
        for op in ops {
            let outcome = match op {
                MutationOp::Insert { sketch, deadline } => {
                    let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                    let (shard, bytes) = self.place_row(id, &sketch, deadline);
                    Ok((shard, bytes, MutationResult::Inserted { id }))
                }
                MutationOp::Delete { id } => self
                    .delete_one(id, None)
                    .map(|placed| {
                        let (shard, bytes) =
                            placed.expect("unconditional delete never skips");
                        (shard, bytes, MutationResult::Deleted { id })
                    }),
                MutationOp::Upsert {
                    id,
                    sketch,
                    deadline,
                } => self
                    .upsert_one(id, &sketch, deadline)
                    .map(|(shard, bytes)| (shard, bytes, MutationResult::Upserted { id })),
            };
            match outcome {
                Ok((shard, bytes, res)) => {
                    if !touched.contains(&shard) {
                        touched.push(shard);
                    }
                    records += 1;
                    wal_bytes += bytes;
                    results.push(res);
                }
                Err(e) => results.push(MutationResult::Failed {
                    error: format!("{e:#}"),
                }),
            }
        }
        touched.sort_unstable();
        if let Some(st) = self.stages() {
            st.write_place.record_us(obs::elapsed_us(place_start));
        }
        let wal_start = Instant::now();
        let mut ticket = MutationTicket {
            windows: Vec::new(),
            records,
            wal_bytes,
            sync_err: None,
        };
        if let Some(p) = &self.persist {
            if records > 0 {
                if p.group_commit_enabled() {
                    for &s in &touched {
                        ticket.windows.push((s, p.group_commit_register(s)));
                    }
                } else {
                    for &s in &touched {
                        let mut w = p.wal_guard(s);
                        if let Err(e) = w.commit() {
                            if ticket.sync_err.is_none() {
                                ticket.sync_err = Some(
                                    anyhow::Error::new(e)
                                        .context(format!("WAL commit for shard {s}")),
                                );
                            }
                        }
                    }
                }
                if let Some(st) = self.stages() {
                    st.write_wal.record_us(obs::elapsed_us(wal_start));
                }
            }
        }
        (results, ticket)
    }

    /// Settle a [`ShardedStore::begin_mutation_batch`] ticket: wait for
    /// every registered commit window, account the WAL traffic, and run
    /// the auto-snapshot trigger. `Err` means some op's frames are in
    /// memory but not durable — the caller must not acknowledge those
    /// ops. Must be called with no store locks held.
    pub fn finish_mutation_batch(&self, ticket: MutationTicket) -> anyhow::Result<()> {
        let MutationTicket {
            windows,
            records,
            wal_bytes,
            sync_err,
        } = ticket;
        if records == 0 {
            return Ok(());
        }
        let mut commit_err = sync_err;
        if let Some(p) = &self.persist {
            if !windows.is_empty() {
                let fsync_start = Instant::now();
                for (shard, epoch) in windows {
                    if let Err(msg) = p.group_commit_wait_epoch(shard, epoch) {
                        if commit_err.is_none() {
                            commit_err =
                                Some(anyhow::anyhow!("group commit for shard {shard}: {msg}"));
                        }
                    }
                }
                if let Some(st) = self.stages() {
                    st.write_fsync.record_us(obs::elapsed_us(fsync_start));
                }
            }
            p.note_appended(records, wal_bytes);
            self.maybe_auto_snapshot();
        }
        match commit_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Delete one id everywhere — arena (swap-remove), LSH index, global
    /// id index, WAL (`Delete` frame) — and commit. Errors if the id is
    /// not resident (never assigned, already deleted, or mid-placement).
    pub fn delete(&self, id: usize) -> anyhow::Result<()> {
        let (mut results, ticket) = self.begin_mutation_batch(vec![MutationOp::Delete { id }]);
        self.finish_mutation_batch(ticket)?;
        match results.pop() {
            Some(MutationResult::Failed { error }) => Err(anyhow::anyhow!(error)),
            _ => Ok(()),
        }
    }

    /// Upsert one id and commit: overwrite the row in place when the id
    /// is resident (same shard, same row — topology untouched), or
    /// re-insert under the original id when it was previously deleted.
    /// Errors if the id was never assigned by an insert.
    pub fn upsert(&self, id: usize, sketch: BitVec, deadline: u64) -> anyhow::Result<()> {
        let (mut results, ticket) =
            self.begin_mutation_batch(vec![MutationOp::Upsert { id, sketch, deadline }]);
        self.finish_mutation_batch(ticket)?;
        match results.pop() {
            Some(MutationResult::Failed { error }) => Err(anyhow::anyhow!(error)),
            _ => Ok(()),
        }
    }

    /// Delete every row whose TTL deadline is `<= now_ms` (and nonzero);
    /// returns how many were swept. Two-phase: a read-locked scan
    /// collects the expired ids, then each is deleted through the
    /// ordinary path — re-checking its deadline under the shard lock, so
    /// an upsert that extended the TTL between scan and delete wins.
    /// Emits ordinary `Delete` frames: on a replicated primary the sweep
    /// is just another mutation on the stream, and followers (which
    /// never sweep) stay bit-identical. Expired-but-unswept rows are
    /// still served until the sweep reaches them — TTL granularity is
    /// the sweep interval, by design.
    pub fn sweep_expired(&self, now_ms: u64) -> usize {
        let expired: Vec<usize> = {
            let _index = read_l(&self.index);
            let mut out = Vec::new();
            for shard in &self.shards {
                let s = read_l(shard);
                out.extend(
                    s.ids
                        .iter()
                        .zip(&s.expiry)
                        .filter(|&(_, &d)| d != 0 && d <= now_ms)
                        .map(|(&id, _)| id),
                );
            }
            out
        };
        if expired.is_empty() {
            return 0;
        }
        let mut touched: Vec<usize> = Vec::new();
        let (mut records, mut wal_bytes) = (0u64, 0u64);
        for id in expired {
            if let Ok(Some((shard, bytes))) = self.delete_one(id, Some(now_ms)) {
                if !touched.contains(&shard) {
                    touched.push(shard);
                }
                records += 1;
                wal_bytes += bytes;
            }
        }
        if records > 0 {
            if let Some(e) = self.commit_shards(&touched) {
                obs_log::warn(
                    "store",
                    "ttl_sweep_commit_failed",
                    &[
                        (
                            "detail",
                            obs_log::V::s(
                                "rows removed in memory; frames stay pending and retry \
                                 with the next commit",
                            ),
                        ),
                        ("error", obs_log::V::s(format!("{e:#}"))),
                    ],
                );
                crate::obs::journal::record(
                    "store",
                    "ttl_sweep_commit_failed",
                    &[("error", obs_log::V::s(format!("{e:#}")))],
                );
            }
            if let Some(p) = &self.persist {
                p.note_appended(records, wal_bytes);
            }
            self.maybe_auto_snapshot();
        }
        records as usize
    }

    /// Place one row under an explicit id (a fresh id from the insert
    /// path, or a deleted id being resurrected by an upsert): least-
    /// loaded shard, arena + LSH + id index + WAL frame under the write
    /// locks, no commit — the caller batches commits per touched shard.
    fn place_row(&self, id: usize, sketch: &BitVec, deadline: u64) -> (usize, u64) {
        let target = self
            .reserved
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.reserved[target].fetch_add(1, Ordering::Relaxed);
        let mut index = write_l(&self.index);
        if index.len() <= id {
            index.resize(id + 1, VACANT);
        }
        let mut shard = write_l(&self.shards[target]);
        let mut wal = self.persist.as_ref().map(|p| p.wal_guard(target));
        let row = shard.rows.len() as u32;
        shard.rows.push(sketch);
        shard.ids.push(id);
        shard.expiry.push(deadline);
        if let Some(ix) = shard.index.as_mut() {
            ix.insert(row as usize, sketch.words());
        }
        let mut bytes = 0u64;
        if let Some(w) = wal.as_deref_mut() {
            bytes = if deadline == 0 {
                w.append_insert(id as u64, sketch.words())
            } else {
                w.append_insert_ttl(id as u64, deadline, sketch.words())
            } as u64;
        }
        index[id] = (target as u32, row);
        (target, bytes)
    }

    /// Remove one resident id; the shared inner of [`ShardedStore::delete`]
    /// and the TTL sweep. With `only_expired_at = Some(now)`, the row's
    /// deadline is re-checked under the shard lock and a no-longer-expired
    /// row is skipped (`Ok(None)`). On removal returns the touched shard
    /// and the appended WAL bytes; the caller commits.
    fn delete_one(
        &self,
        id: usize,
        only_expired_at: Option<u64>,
    ) -> anyhow::Result<Option<(usize, u64)>> {
        let mut index = write_l(&self.index);
        let (s, r) = match index.get(id) {
            Some(&slot) if slot != VACANT => (slot.0 as usize, slot.1 as usize),
            _ => anyhow::bail!("delete of id {id} which the store does not hold"),
        };
        let mut guard = write_l(&self.shards[s]);
        let sh = &mut *guard;
        if let Some(now) = only_expired_at {
            let d = sh.expiry[r];
            if d == 0 || d > now {
                return Ok(None);
            }
        }
        let mut wal = self.persist.as_ref().map(|p| p.wal_guard(s));
        let last = sh.rows.len() - 1;
        let removed: Vec<u64> = sh.rows.row(r).to_vec();
        sh.rows.swap_remove_row(r);
        sh.ids.swap_remove(r);
        sh.expiry.swap_remove(r);
        if let Some(ix) = sh.index.as_mut() {
            if r == last {
                ix.remove_last(&removed);
            } else {
                ix.remove_at(r, &removed, sh.rows.row(r));
            }
        }
        let mut bytes = 0u64;
        if let Some(w) = wal.as_deref_mut() {
            bytes = w.append_delete(id as u64) as u64;
        }
        index[id] = VACANT;
        if r != last {
            // the trailing row dropped into the hole: re-home its id
            let swapped = sh.ids[r];
            index[swapped] = (s as u32, r as u32);
        }
        self.reserved[s].fetch_sub(1, Ordering::Relaxed);
        drop(wal);
        drop(guard);
        drop(index);
        if let Some(p) = &self.persist {
            // the row's insert frame and this delete frame both die at
            // the next rotation
            p.note_dead_frames(2);
        }
        Ok(Some((s, bytes)))
    }

    /// Overwrite or resurrect one id; the inner of
    /// [`ShardedStore::upsert`]. Returns the touched shard and the
    /// appended WAL bytes; the caller commits.
    fn upsert_one(
        &self,
        id: usize,
        sketch: &BitVec,
        deadline: u64,
    ) -> anyhow::Result<(usize, u64)> {
        anyhow::ensure!(
            id < self.next_id.load(Ordering::Relaxed),
            "upsert of id {id} which was never assigned — inserts allocate ids"
        );
        let mut index = write_l(&self.index);
        let slot = index.get(id).copied().unwrap_or(VACANT);
        if slot == VACANT {
            // previously deleted (or its placement aborted): re-insert
            // under the same id — delete + insert, collapsed
            drop(index);
            return Ok(self.place_row(id, sketch, deadline));
        }
        let (s, r) = (slot.0 as usize, slot.1 as usize);
        let mut guard = write_l(&self.shards[s]);
        let sh = &mut *guard;
        let old: Vec<u64> = sh.rows.row(r).to_vec();
        let mut wal = self.persist.as_ref().map(|p| p.wal_guard(s));
        let weight = popcount_words(sketch.words()) as u32;
        sh.rows.overwrite_row(r, sketch.words(), weight);
        sh.expiry[r] = deadline;
        if let Some(ix) = sh.index.as_mut() {
            ix.update_row(r, &old, sketch.words());
        }
        let mut bytes = 0u64;
        if let Some(w) = wal.as_deref_mut() {
            bytes = w.append_upsert(id as u64, deadline, sketch.words()) as u64;
        }
        drop(wal);
        drop(guard);
        drop(index);
        if let Some(p) = &self.persist {
            // the row's previous insert/upsert frame dies at the next
            // rotation
            p.note_dead_frames(1);
        }
        Ok((s, bytes))
    }

    /// Commit the named shards' WALs — synchronously, or through the
    /// open group-commit window when one is configured. Returns the
    /// first error, if any. Must be called with no store locks held.
    fn commit_shards(&self, touched: &[usize]) -> Option<anyhow::Error> {
        let p = self.persist.as_ref()?;
        let mut first_err = None;
        if p.group_commit_enabled() {
            let epochs: Vec<(usize, u64)> = touched
                .iter()
                .map(|&s| (s, p.group_commit_register(s)))
                .collect();
            for (s, e) in epochs {
                if let Err(msg) = p.group_commit_wait_epoch(s, e) {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("group commit for shard {s}: {msg}"));
                    }
                }
            }
        } else {
            for &s in touched {
                let mut w = p.wal_guard(s);
                if let Err(e) = w.commit() {
                    if first_err.is_none() {
                        first_err = Some(
                            anyhow::Error::new(e).context(format!("WAL commit for shard {s}")),
                        );
                    }
                }
            }
        }
        first_err
    }

    /// Resolve an id to its current `(shard, row)` in O(1).
    pub fn locate(&self, id: usize) -> Option<(usize, usize)> {
        let index = read_l(&self.index);
        match index.get(id) {
            Some(&(s, r)) if (s, r) != VACANT => Some((s as usize, r as usize)),
            _ => None,
        }
    }

    /// Fetch a sketch by global id — an index lookup plus one row copy,
    /// O(1) in the corpus size.
    pub fn get(&self, id: usize) -> Option<BitVec> {
        let index = read_l(&self.index);
        match index.get(id) {
            Some(&(s, r)) if (s, r) != VACANT => {
                let shard = read_l(&self.shards[s as usize]);
                Some(shard.rows.row_bitvec(r as usize))
            }
            _ => None,
        }
    }

    /// Pairwise estimator inputs `(|ũ|, |ṽ|, ⟨ũ,ṽ⟩)` for two stored ids,
    /// computed on borrowed arena rows — no sketch is cloned.
    pub fn pair_stats(&self, a: usize, b: usize) -> Option<(usize, usize, usize)> {
        let index = read_l(&self.index);
        let &(sa, ra) = index.get(a)?;
        let &(sb, rb) = index.get(b)?;
        if (sa, ra) == VACANT || (sb, rb) == VACANT {
            return None;
        }
        let (sa, ra, sb, rb) = (sa as usize, ra as usize, sb as usize, rb as usize);
        if sa == sb {
            let shard = read_l(&self.shards[sa]);
            return Some((
                shard.rows.weight(ra),
                shard.rows.weight(rb),
                and_count_words(shard.rows.row(ra), shard.rows.row(rb)),
            ));
        }
        // distinct shards: acquire read locks in ascending shard order
        let (lo, hi) = (sa.min(sb), sa.max(sb));
        let first = read_l(&self.shards[lo]);
        let second = read_l(&self.shards[hi]);
        let (shard_a, shard_b) = if sa == lo {
            (&first, &second)
        } else {
            (&second, &first)
        };
        Some((
            shard_a.rows.weight(ra),
            shard_b.rows.weight(rb),
            and_count_words(shard_a.rows.row(ra), shard_b.rows.row(rb)),
        ))
    }

    /// Run `f` over every shard (read-locked) and collect results.
    pub fn map_shards<T, F: Fn(&Shard) -> T>(&self, f: F) -> Vec<T> {
        self.shards.iter().map(|s| f(&read_l(s))).collect()
    }

    /// Parallel scatter over the *persistent* shard executor: `make(si)`
    /// builds shard `si`'s job, which runs read-locked on that shard's
    /// long-lived worker thread; results come back in shard order. This is
    /// the serving scatter — no thread is spawned per request.
    pub fn scatter_gather<T, F>(&self, make: F) -> Vec<T>
    where
        T: Send + 'static,
        F: FnMut(usize) -> Box<dyn FnOnce(&Shard) -> T + Send>,
    {
        self.executor.scatter_gather(make)
    }

    /// The store's executor runtime (counters, worker count).
    pub fn executor(&self) -> &ShardExecutor {
        &self.executor
    }

    /// Scoped-spawn scatter: spawns one OS thread per shard for this call.
    /// Superseded by [`ShardedStore::scatter_gather`] on every serving
    /// path; kept as the measured baseline in `bench_router` and as a
    /// borrow-friendly convenience for tests (its closures may borrow the
    /// caller's stack, which the persistent executor's `'static` jobs
    /// cannot).
    pub fn par_map_shards<T: Send, F: Fn(&Shard) -> T + Sync>(&self, f: F) -> Vec<T> {
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|s| {
                    let f = &f;
                    scope.spawn(move || f(&read_l(s)))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    /// All sketches in id order (testing/heatmaps on small corpora).
    ///
    /// Holds the index read lock for the duration: a concurrent rebalance
    /// (which holds the index *write* lock for every move) can therefore
    /// never shuttle a row from an already-read shard into a
    /// not-yet-read one mid-walk — no duplicated or dropped rows.
    pub fn snapshot_ordered(&self) -> Vec<(usize, BitVec)> {
        let _index = read_l(&self.index);
        let mut all: Vec<(usize, BitVec)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let s = read_l(shard);
            all.extend(
                s.ids
                    .iter()
                    .enumerate()
                    .map(|(row, &id)| (id, s.rows.row_bitvec(row))),
            );
        }
        all.sort_by_key(|&(id, _)| id);
        all
    }

    /// Id-ordered snapshot packed into one arena — the input the all-pairs
    /// analysis paths scan directly. Rows are copied arena-to-arena with
    /// their cached weights: no per-row `BitVec` allocation, no popcount.
    /// Same consistency protocol as [`ShardedStore::snapshot_ordered`]:
    /// index read lock first, then all shard read locks in ascending order.
    pub fn snapshot_matrix(&self) -> SketchMatrix {
        let _index = read_l(&self.index);
        let guards: Vec<_> = self.shards.iter().map(|s| read_l(s)).collect();
        let n: usize = guards.iter().map(|g| g.ids.len()).sum();
        let mut order: Vec<(usize, usize, usize)> = Vec::with_capacity(n);
        for (si, g) in guards.iter().enumerate() {
            order.extend(g.ids.iter().enumerate().map(|(ri, &id)| (id, si, ri)));
        }
        order.sort_unstable_by_key(|&(id, _, _)| id);
        let mut m = SketchMatrix::with_row_capacity(self.sketch_dim, order.len());
        for (_, si, ri) in order {
            m.push_row(guards[si].rows.row(ri), guards[si].rows.weight(ri) as u32);
        }
        m
    }

    /// Shard occupancy (balance diagnostics / rebalance tests).
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.map_shards(|s| s.ids.len())
    }

    /// Force a snapshot rotation now (the `snapshot` wire op, and the
    /// auto-snapshot trigger). Stop-the-world: holds the id-index read
    /// lock (blocking inserts and rebalances), every shard read lock and
    /// every WAL mutex while the new generation is cut, so the snapshot +
    /// empty-WAL pair is an exact point-in-time image. Returns the new
    /// generation.
    pub fn persist_snapshot(&self) -> anyhow::Result<u64> {
        let p = self
            .persist
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("persistence is disabled on this store"))?;
        let _index = read_l(&self.index);
        let guards: Vec<_> = self.shards.iter().map(|s| read_l(s)).collect();
        let views: Vec<(&[usize], &[u64], &SketchMatrix)> = guards
            .iter()
            .map(|g| (g.ids.as_slice(), g.expiry.as_slice(), &g.rows))
            .collect();
        let mut wals: Vec<_> = (0..self.shards.len()).map(|i| p.wal_guard(i)).collect();
        p.write_snapshot(&views, &mut wals)
    }

    /// Flush and fsync every shard WAL (the `flush` wire op and graceful
    /// shutdown) — upgrades `fsync = never` data to durable on demand.
    pub fn persist_flush(&self) -> anyhow::Result<()> {
        let p = self
            .persist
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("persistence is disabled on this store"))?;
        p.flush_all()
    }

    /// Apply a chunk of replicated WAL frames to `shard` — the follower
    /// side of log shipping (see [`crate::replica`]). `records` must be
    /// the decoded view of `raw_frames` (the follower validates the
    /// shipped bytes with [`crate::persist::wal::scan_frames`], which is
    /// also the transfer-integrity check: every frame is length-prefixed
    /// and checksummed).
    ///
    /// Mirrors each record into the arena / id column / per-shard LSH
    /// index / global id index exactly as the primary's mutators did —
    /// under the same lock order (id index → shard → WAL mutex) — then
    /// appends the raw bytes verbatim to this store's own WAL and commits
    /// them synchronously, so both logs stay byte-identical
    /// position-for-position and an applied chunk survives a follower
    /// restart through the ordinary recovery path.
    ///
    /// An infeasible chunk (a `MoveOut` against an empty arena, or a
    /// `Delete`/`Upsert` of an id the shard does not hold — the
    /// signature of divergence, not transfer damage) is rejected *before
    /// any mutation*: the pre-pass simulates the whole chunk against a
    /// copy of the shard's id column, so a failed apply leaves the shard
    /// untouched. A WAL commit failure leaves the frames writer-pending:
    /// they are counted by [`Persistence::next_seq`] (so the puller does
    /// not re-request and double-apply them) and retried by the next
    /// chunk's commit.
    ///
    /// Cross-shard note: a rebalance move ships as a `MoveIn`
    /// (destination log) / `MoveOut` (source log) pair stamped with the
    /// same move id. The two shards' streams still apply independently,
    /// but the puller uses the shared id to hold a `MoveOut` back until
    /// its paired `MoveIn` has been applied (see [`crate::replica`]), so
    /// a caught-up reader only ever observes the benign
    /// duplicate-copies state (row transiently in both shards — exactly
    /// what crash recovery already dedups), never the row absent from
    /// both. The `MoveOut` only clears the id-index entry if it still
    /// points at the popped row, so the index never aliases a wrong row
    /// either way.
    pub fn apply_replicated(
        &self,
        shard: usize,
        raw_frames: &[u8],
        records: &[WalRecord],
    ) -> anyhow::Result<()> {
        let p = self
            .persist
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("replication apply requires a durable store"))?;
        anyhow::ensure!(shard < self.shards.len(), "shard {shard} out of range");
        if records.is_empty() {
            return Ok(());
        }
        let mut index = write_l(&self.index);
        let mut guard = write_l(&self.shards[shard]);
        let sh = &mut *guard;
        // Feasibility pre-pass: simulate the chunk against a copy of the
        // id column (positions matter — Delete swap-removes) and reject
        // divergent chunks before any mutation. The positions each
        // Delete/Upsert resolves to are queued for the apply loop below,
        // which therefore cannot fail mid-chunk. Also tally the frames
        // this chunk obsoletes so the follower's own compaction trigger
        // tracks the primary's.
        let mut sim: Vec<usize> = sh.ids.clone();
        let mut at: std::collections::HashMap<usize, usize> =
            sim.iter().enumerate().map(|(r, &id)| (id, r)).collect();
        let mut touch_pos = std::collections::VecDeque::new();
        let mut dead_frames = 0u64;
        for rec in records {
            match rec {
                WalRecord::Insert { id, .. } | WalRecord::MoveIn { id, .. } => {
                    let id = *id as usize;
                    at.insert(id, sim.len());
                    sim.push(id);
                }
                WalRecord::MoveOut { .. } => {
                    let Some(id) = sim.pop() else {
                        anyhow::bail!(
                            "replicated MoveOut against an empty shard {shard} — \
                             follower has diverged from the primary's log"
                        );
                    };
                    at.remove(&id);
                }
                WalRecord::Delete { id } => {
                    let id = *id as usize;
                    let Some(pos) = at.remove(&id) else {
                        anyhow::bail!(
                            "replicated Delete of id {id} which shard {shard} does not \
                             hold — follower has diverged from the primary's log"
                        );
                    };
                    sim.swap_remove(pos);
                    if pos < sim.len() {
                        at.insert(sim[pos], pos);
                    }
                    touch_pos.push_back(pos);
                    dead_frames += 2;
                }
                WalRecord::Upsert { id, .. } => {
                    let id = *id as usize;
                    let Some(&pos) = at.get(&id) else {
                        anyhow::bail!(
                            "replicated Upsert of id {id} which shard {shard} does not \
                             hold — follower has diverged from the primary's log"
                        );
                    };
                    touch_pos.push_back(pos);
                    dead_frames += 1;
                }
            }
        }
        let mut wal = p.wal_guard(shard);
        for rec in records {
            match rec {
                WalRecord::Insert {
                    id,
                    deadline,
                    words,
                }
                | WalRecord::MoveIn {
                    id,
                    deadline,
                    words,
                    ..
                } => {
                    let id = *id as usize;
                    let row = sh.rows.len();
                    let weight = popcount_words(words) as u32;
                    sh.rows.push_row(words, weight);
                    sh.ids.push(id);
                    sh.expiry.push(*deadline);
                    if let Some(ix) = sh.index.as_mut() {
                        ix.insert(row, words);
                    }
                    if index.len() <= id {
                        index.resize(id + 1, VACANT);
                    }
                    index[id] = (shard as u32, row as u32);
                    self.next_id.fetch_max(id + 1, Ordering::Relaxed);
                    self.reserved[shard].fetch_add(1, Ordering::Relaxed);
                }
                WalRecord::MoveOut { .. } => {
                    let id = sh.ids.pop().expect("pre-pass guarantees a non-empty shard");
                    sh.expiry.pop();
                    let row = sh.rows.len() - 1;
                    if let Some(ix) = sh.index.as_mut() {
                        ix.remove_last(sh.rows.row(row));
                    }
                    sh.rows.pop_row();
                    // the paired MoveIn may already have re-homed this id
                    if index.get(id) == Some(&(shard as u32, row as u32)) {
                        index[id] = VACANT;
                    }
                    self.reserved[shard].fetch_sub(1, Ordering::Relaxed);
                }
                WalRecord::Delete { id } => {
                    let id = *id as usize;
                    let pos = touch_pos
                        .pop_front()
                        .expect("pre-pass resolved every Delete");
                    let last = sh.rows.len() - 1;
                    let removed: Vec<u64> = sh.rows.row(pos).to_vec();
                    sh.rows.swap_remove_row(pos);
                    sh.ids.swap_remove(pos);
                    sh.expiry.swap_remove(pos);
                    if let Some(ix) = sh.index.as_mut() {
                        if pos == last {
                            ix.remove_last(&removed);
                        } else {
                            ix.remove_at(pos, &removed, sh.rows.row(pos));
                        }
                    }
                    // conditionals mirror MoveOut: in the transient
                    // duplicate-copies state another shard's copy may
                    // already own the index entry
                    if index.get(id) == Some(&(shard as u32, pos as u32)) {
                        index[id] = VACANT;
                    }
                    if pos != last {
                        let swapped = sh.ids[pos];
                        if index.get(swapped) == Some(&(shard as u32, last as u32)) {
                            index[swapped] = (shard as u32, pos as u32);
                        }
                    }
                    self.reserved[shard].fetch_sub(1, Ordering::Relaxed);
                }
                WalRecord::Upsert {
                    deadline, words, ..
                } => {
                    let pos = touch_pos
                        .pop_front()
                        .expect("pre-pass resolved every Upsert");
                    let old: Vec<u64> = sh.rows.row(pos).to_vec();
                    let weight = popcount_words(words) as u32;
                    sh.rows.overwrite_row(pos, words, weight);
                    sh.expiry[pos] = *deadline;
                    if let Some(ix) = sh.index.as_mut() {
                        ix.update_row(pos, &old, words);
                    }
                }
            }
        }
        if dead_frames > 0 {
            p.note_dead_frames(dead_frames);
        }
        wal.append_raw(raw_frames, records.len() as u64);
        // commit outside the shard/index locks (mirroring the primary's
        // insert path): a disk flush must not block this replica's readers
        drop(guard);
        drop(index);
        let commit = wal
            .commit()
            .with_context(|| format!("committing replicated frames for shard {shard}"));
        drop(wal);
        p.note_appended(records.len() as u64, raw_frames.len() as u64);
        self.maybe_auto_snapshot();
        commit
    }

    /// Rotate a snapshot if the auto-snapshot threshold was crossed. Must
    /// be called with no store locks held (snapshotting takes them all).
    /// The claim is atomic: one rotation per threshold crossing even under
    /// concurrent inserters, and a failed rotation is deferred by a full
    /// interval (WAL-only degradation) instead of re-attempted on every
    /// subsequent batch.
    fn maybe_auto_snapshot(&self) {
        if let Some(p) = &self.persist {
            if p.try_claim_auto_snapshot() {
                if let Err(e) = self.persist_snapshot() {
                    obs_log::warn(
                        "store",
                        "auto_snapshot_failed",
                        &[
                            (
                                "detail",
                                obs_log::V::s(
                                    "retrying after the next interval, WAL-only until then",
                                ),
                            ),
                            ("error", obs_log::V::s(format!("{e:#}"))),
                        ],
                    );
                    crate::obs::journal::record(
                        "store",
                        "auto_snapshot_failed",
                        &[("error", obs_log::V::s(format!("{e:#}")))],
                    );
                }
            }
        }
    }

    /// Rebalance: move whole trailing runs from over-full to under-full
    /// shards until max-min ≤ tolerance, keeping the id index consistent.
    /// Returns number of moved sketches. Durable stores log every move as
    /// a `MoveOut`/`MoveIn` pair in the two shards' WALs, under the same
    /// write locks that perform it.
    pub fn rebalance(&self, tolerance: usize) -> usize {
        let mut moved = 0;
        let mut wal_records = 0u64;
        let mut wal_bytes = 0u64;
        loop {
            // index lock first (global lock order), so lookups never see a
            // half-moved row.
            let mut index = write_l(&self.index);
            let sizes = self.shard_sizes();
            let (max_i, &max_v) = sizes
                .iter()
                .enumerate()
                .max_by_key(|&(_, v)| *v)
                .unwrap();
            let (min_i, &min_v) = sizes
                .iter()
                .enumerate()
                .min_by_key(|&(_, v)| *v)
                .unwrap();
            if max_v <= min_v + tolerance.max(1) {
                break;
            }
            let take = (max_v - min_v) / 2;
            // shard locks in ascending order (see module docs)
            let (lo, hi) = (max_i.min(min_i), max_i.max(min_i));
            let (first, second) = (write_l(&self.shards[lo]), write_l(&self.shards[hi]));
            let (mut src, mut dst) = if max_i == lo {
                (first, second)
            } else {
                (second, first)
            };
            // WAL mutexes last (strict leaves), ascending shard order.
            let mut wals = self.persist.as_ref().map(|p| {
                let first = p.wal_guard(lo);
                let second = p.wal_guard(hi);
                if max_i == lo {
                    (first, second) // (src, dst)
                } else {
                    (second, first)
                }
            });
            // Under group commit the source writer may already hold a
            // concurrent insert batch's uncommitted frames (appended
            // before we took this mutex, awaiting the window flush). Mark
            // where OUR frames start so a failed destination commit can
            // rewind exactly the move-outs and nothing else.
            let src_mark = wals.as_ref().map(|(src_w, _)| src_w.pending_watermark());
            // Split the guards into disjoint field borrows so the LSH
            // indexes can be maintained against the arenas in the same
            // pass. Each move pops src's *trailing* row and appends it to
            // dst, so existing row positions in both arenas are untouched:
            // the indexes follow along incrementally — O(L) per moved row
            // (`remove_last` + `insert`), not an O(rows · L) rebuild —
            // all under the write locks, so no reader can observe an
            // index out of step with its arena.
            let src = &mut *src;
            let dst = &mut *dst;
            let mut moved_here = 0;
            for _ in 0..take {
                let Some(id) = src.ids.pop() else { break };
                // the TTL deadline travels with the row across the move
                let deadline = src.expiry.pop().unwrap_or(0);
                src.rows.move_last_row_to(&mut dst.rows);
                dst.ids.push(id);
                dst.expiry.push(deadline);
                let new_row = dst.rows.len() - 1;
                let words = dst.rows.row(new_row);
                if let Some(ix) = src.index.as_mut() {
                    ix.remove_last(words);
                }
                if let Some(ix) = dst.index.as_mut() {
                    ix.insert(new_row, words);
                }
                if let Some((src_w, dst_w)) = wals.as_mut() {
                    // one fresh move id stamps the pair so a replication
                    // puller can match them up across the two shard logs
                    let mid = self.move_id.fetch_add(1, Ordering::Relaxed);
                    wal_bytes += src_w.append_move_out(mid) as u64;
                    wal_bytes += dst_w.append_move_in(mid, id as u64, deadline, words) as u64;
                    wal_records += 2;
                }
                index[id] = (min_i as u32, new_row as u32);
                moved_here += 1;
            }
            // Commit the destination (MoveIn) before the source (MoveOut):
            // a crash between the two commits then at worst leaves the row
            // present in both logs — benign, since both copies carry
            // identical words and recovery dedups repeated ids — never
            // absent from both, which would lose an acknowledged insert.
            // If the destination commit FAILS, the paired MoveOuts must be
            // discarded, not left pending: a later commit on the source
            // shard would otherwise make them durable alone and re-open
            // exactly that loss window. The rewind is to OUR watermark,
            // not a full clear — frames buffered before it belong to a
            // concurrent group-commit insert batch whose ack depends on
            // them reaching the file.
            if let Some((mut src_w, mut dst_w)) = wals {
                match dst_w.commit() {
                    Ok(()) => {
                        if let Err(e) = src_w.commit() {
                            obs_log::error(
                                "store",
                                "rebalance_src_commit_failed",
                                &[("error", obs_log::V::s(format!("{e}")))],
                            );
                        }
                    }
                    Err(e) => {
                        if let Some(mark) = src_mark {
                            src_w.rewind_pending_to(mark);
                        }
                        obs_log::error(
                            "store",
                            "rebalance_dst_commit_failed",
                            &[
                                (
                                    "detail",
                                    obs_log::V::s(
                                        "paired move-outs discarded; rows recover as \
                                         duplicates at worst",
                                    ),
                                ),
                                ("error", obs_log::V::s(format!("{e}"))),
                            ],
                        );
                    }
                }
            }
            // keep the placement reservations exact across moves
            self.reserved[max_i].fetch_sub(moved_here, Ordering::Relaxed);
            self.reserved[min_i].fetch_add(moved_here, Ordering::Relaxed);
            moved += moved_here;
            if moved_here == 0 {
                break;
            }
        }
        if wal_records > 0 {
            if let Some(p) = &self.persist {
                p.note_appended(wal_records, wal_bytes);
            }
            self.maybe_auto_snapshot();
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::{FsyncPolicy, PersistMode};
    use crate::testing::TempDir;
    use crate::util::rng::Xoshiro256;

    fn sk(rng: &mut Xoshiro256, d: usize) -> BitVec {
        BitVec::from_indices(d, rng.sample_indices(d, d / 8))
    }

    #[test]
    fn ids_are_unique_and_dense() {
        let store = ShardedStore::new(4, 64);
        let mut rng = Xoshiro256::new(1);
        let mut all_ids = Vec::new();
        for _ in 0..10 {
            let batch: Vec<BitVec> = (0..5).map(|_| sk(&mut rng, 64)).collect();
            all_ids.extend(store.insert_batch(batch));
        }
        all_ids.sort_unstable();
        assert_eq!(all_ids, (0..50).collect::<Vec<_>>());
        assert_eq!(store.len(), 50);
    }

    #[test]
    fn get_retrieves_inserted() {
        let store = ShardedStore::new(3, 32);
        let mut rng = Xoshiro256::new(2);
        let a = sk(&mut rng, 32);
        let b = sk(&mut rng, 32);
        let ids = store.insert_batch(vec![a.clone(), b.clone()]);
        assert_eq!(store.get(ids[0]).unwrap(), a);
        assert_eq!(store.get(ids[1]).unwrap(), b);
        assert!(store.get(999).is_none());
        assert!(store.locate(ids[0]).is_some());
        assert!(store.locate(999).is_none());
    }

    #[test]
    fn pair_stats_match_bitvec_ops() {
        let store = ShardedStore::new(3, 128);
        let mut rng = Xoshiro256::new(7);
        let pts: Vec<BitVec> = (0..9).map(|_| sk(&mut rng, 128)).collect();
        let mut ids = Vec::new();
        for p in pts.chunks(2) {
            ids.extend(store.insert_batch(p.to_vec()));
        }
        for i in 0..pts.len() {
            for j in 0..pts.len() {
                let (wa, wb, ip) = store.pair_stats(ids[i], ids[j]).unwrap();
                assert_eq!(wa, pts[i].count_ones());
                assert_eq!(wb, pts[j].count_ones());
                assert_eq!(ip, pts[i].and_count(&pts[j]));
            }
        }
        assert!(store.pair_stats(0, 999).is_none());
    }

    #[test]
    fn balancing_across_shards() {
        let store = ShardedStore::new(4, 16);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..16 {
            store.insert_batch(vec![sk(&mut rng, 16)]);
        }
        let sizes = store.shard_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 16);
        assert!(sizes.iter().all(|&s| s == 4), "{sizes:?}");
    }

    #[test]
    fn single_client_batches_spread() {
        // Regression for the seed's least-loaded scan, which observed a
        // shard's size only after its batch fully landed: a sequence of
        // equal-size batches from one client must stripe across shards.
        let store = ShardedStore::new(3, 16);
        let mut rng = Xoshiro256::new(9);
        for _ in 0..9 {
            store.insert_batch((0..4).map(|_| sk(&mut rng, 16)).collect());
        }
        assert_eq!(store.shard_sizes(), vec![12, 12, 12]);
    }

    #[test]
    fn variable_size_batches_stay_point_balanced() {
        // The dynamic batcher interleaves deadline flushes (tiny) with
        // size flushes (large). Placement must balance *points*, not
        // batch counts — batch-count round-robin would send every large
        // batch to one shard here (diff 60), reservation keeps the gap
        // within one max batch.
        let store = ShardedStore::new(2, 16);
        let mut rng = Xoshiro256::new(10);
        for _ in 0..10 {
            store.insert_batch(vec![sk(&mut rng, 16)]);
            store.insert_batch((0..7).map(|_| sk(&mut rng, 16)).collect());
        }
        let sizes = store.shard_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 80);
        let (max, min) = (*sizes.iter().max().unwrap(), *sizes.iter().min().unwrap());
        assert!(max - min <= 7, "{sizes:?}");
    }

    #[test]
    fn concurrent_inserters_stay_balanced() {
        // Regression for the racy read-then-write placement: concurrent
        // batchers used to observe the same "least-loaded" shard and all
        // pile onto it. Reservations are bumped before rows land, so later
        // scans steer away immediately.
        let store = ShardedStore::new(4, 32);
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let store = &store;
                scope.spawn(move || {
                    let mut rng = Xoshiro256::new(100 + t);
                    for _ in 0..6 {
                        store.insert_batch((0..4).map(|_| sk(&mut rng, 32)).collect());
                    }
                });
            }
        });
        let sizes = store.shard_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 8 * 6 * 4);
        let (max, min) = (
            *sizes.iter().max().unwrap(),
            *sizes.iter().min().unwrap(),
        );
        // 48 batches over 4 shards: reservation keeps occupancy level to
        // within a batch or two (a simultaneous-scan tie can double-place
        // one round; the next scans correct it).
        assert!(max - min <= 8, "{sizes:?}");
    }

    #[test]
    fn rebalance_conserves_and_levels() {
        let store = ShardedStore::new(2, 16);
        let mut rng = Xoshiro256::new(4);
        // imbalance: one big batch lands on a single shard
        store.insert_batch((0..20).map(|_| sk(&mut rng, 16)).collect());
        let before: usize = store.shard_sizes().iter().sum();
        let moved = store.rebalance(1);
        let after = store.shard_sizes();
        assert_eq!(after.iter().sum::<usize>(), before);
        assert!(moved > 0);
        assert!((after[0] as i64 - after[1] as i64).abs() <= 2, "{after:?}");
        // everything still retrievable
        let snap = store.snapshot_ordered();
        assert_eq!(snap.len(), 20);
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn rebalance_keeps_index_consistent() {
        let store = ShardedStore::new(3, 64);
        let mut rng = Xoshiro256::new(5);
        let pts: Vec<BitVec> = (0..30).map(|_| sk(&mut rng, 64)).collect();
        let ids = store.insert_batch(pts.clone());
        store.rebalance(1);
        // O(1) lookups must still resolve every id to its (possibly moved)
        // row, and return the original sketch.
        for (id, pt) in ids.iter().zip(&pts) {
            assert_eq!(store.get(*id).as_ref(), Some(pt), "id {id}");
            let (s, r) = store.locate(*id).unwrap();
            // the shard's own id column agrees with the index
            let shard_ids = store.map_shards(|sh| sh.ids.clone());
            assert_eq!(shard_ids[s][r], *id);
        }
    }

    #[test]
    fn snapshot_matrix_is_id_ordered() {
        let store = ShardedStore::new(3, 48);
        let mut rng = Xoshiro256::new(6);
        let pts: Vec<BitVec> = (0..11).map(|_| sk(&mut rng, 48)).collect();
        for p in pts.chunks(3) {
            store.insert_batch(p.to_vec());
        }
        let m = store.snapshot_matrix();
        assert_eq!(m.len(), 11);
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(m.row_bitvec(i), *p, "row {i}");
        }
    }

    fn on_cfg() -> IndexConfig {
        IndexConfig {
            mode: crate::index::IndexMode::On,
            ..Default::default()
        }
    }

    #[test]
    fn indexed_store_mirrors_every_insert() {
        let store = ShardedStore::with_index(3, 128, &on_cfg(), 7);
        let mut rng = Xoshiro256::new(21);
        for _ in 0..6 {
            store.insert_batch((0..5).map(|_| sk(&mut rng, 128)).collect());
        }
        for (rows, ix_len) in
            store.map_shards(|s| (s.ids.len(), s.index.as_ref().map(|ix| ix.len())))
        {
            assert_eq!(ix_len, Some(rows), "index out of step with arena");
        }
    }

    #[test]
    fn index_off_builds_no_shard_indexes() {
        let off = IndexConfig {
            mode: crate::index::IndexMode::Off,
            ..Default::default()
        };
        let store = ShardedStore::with_index(2, 64, &off, 7);
        assert!(store
            .map_shards(|s| s.index.is_none())
            .into_iter()
            .all(|none| none));
        // plain `new` likewise
        let plain = ShardedStore::new(2, 64);
        assert!(plain
            .map_shards(|s| s.index.is_none())
            .into_iter()
            .all(|none| none));
    }

    #[test]
    fn rebalance_keeps_shard_indexes_consistent() {
        let store = ShardedStore::with_index(2, 128, &on_cfg(), 5);
        let mut rng = Xoshiro256::new(22);
        // one big batch lands on a single shard → rebalance must move rows
        let pts: Vec<BitVec> = (0..40).map(|_| sk(&mut rng, 128)).collect();
        store.insert_batch(pts.clone());
        assert!(store.rebalance(1) > 0);
        // incrementally maintained indexes track the post-move arenas...
        for (shard_rows, ix_len) in
            store.map_shards(|s| (s.ids.len(), s.index.as_ref().map(|ix| ix.len())))
        {
            assert_eq!(ix_len, Some(shard_rows));
        }
        // ...and every moved row is still findable through its new shard's
        // index (an exact-duplicate query must collide in every band).
        for (i, p) in pts.iter().enumerate() {
            let (s, r) = store.locate(i).unwrap();
            let found = store.map_shards(|sh| {
                sh.index
                    .as_ref()
                    .map(|ix| ix.candidates(p.words()).0)
                    .unwrap_or_default()
            });
            assert!(
                found[s].binary_search(&(r as u32)).is_ok(),
                "id {i} missing from shard {s} index after rebalance"
            );
        }
    }

    #[test]
    fn par_map_matches_map() {
        let store = ShardedStore::new(4, 16);
        let mut rng = Xoshiro256::new(5);
        for _ in 0..8 {
            store.insert_batch(vec![sk(&mut rng, 16)]);
        }
        let a = store.map_shards(|s| s.ids.len());
        let b = store.par_map_shards(|s| s.ids.len());
        assert_eq!(a, b);
    }

    #[test]
    fn scatter_gather_matches_map_and_counts_jobs() {
        let store = ShardedStore::new(4, 16);
        let mut rng = Xoshiro256::new(15);
        for _ in 0..10 {
            store.insert_batch(vec![sk(&mut rng, 16)]);
        }
        let a = store.map_shards(|s| s.ids.len());
        let b = store.scatter_gather(|_si| Box::new(|s: &Shard| s.ids.len()));
        assert_eq!(a, b);
        let counters = store.executor().counters();
        assert_eq!(counters.scatters.load(Ordering::Relaxed), 1);
        assert_eq!(counters.jobs.load(Ordering::Relaxed), 4);
        assert_eq!(counters.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn scatter_gather_races_inserts_without_losing_or_duplicating_hits() {
        // Executor lifecycle under load: scatters interleave with raw
        // inserts; every scan must see each id at most once (no shard
        // visits a row twice) and must always see the pre-inserted prefix
        // (append-only arenas: a row, once placed, is visible to every
        // later scan).
        let store = Arc::new(ShardedStore::new(3, 64));
        let mut rng = Xoshiro256::new(16);
        let baseline: Vec<BitVec> = (0..30).map(|_| sk(&mut rng, 64)).collect();
        let base_ids = store.insert_batch(baseline);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            let writer = {
                let store = store.clone();
                let stop = stop.clone();
                scope.spawn(move || {
                    let mut rng = Xoshiro256::new(17);
                    while !stop.load(Ordering::SeqCst) {
                        store.insert_batch((0..3).map(|_| sk(&mut rng, 64)).collect());
                    }
                })
            };
            for _ in 0..50 {
                let seen: Vec<Vec<usize>> =
                    store.scatter_gather(|_si| Box::new(|s: &Shard| s.ids.clone()));
                let mut all: Vec<usize> = seen.into_iter().flatten().collect();
                let total = all.len();
                all.sort_unstable();
                all.dedup();
                assert_eq!(all.len(), total, "a scatter saw an id twice");
                for id in &base_ids {
                    assert!(all.binary_search(id).is_ok(), "id {id} lost mid-scatter");
                }
            }
            stop.store(true, Ordering::SeqCst);
            writer.join().unwrap();
        });
    }

    #[test]
    fn group_commit_coalesces_and_survives_reopen() {
        let dir = TempDir::new("store-group-commit");
        let cfg = PersistConfig {
            commit_window_us: 2_000,
            // group commit only engages with an fsync to amortise
            fsync: FsyncPolicy::Always,
            ..durable_cfg(&dir, PersistMode::Wal, 0)
        };
        let counters = Arc::new(PersistCounters::default());
        let expected = {
            let (store, _) = ShardedStore::open_durable(
                fp(2, 64, 5),
                &IndexConfig::default(),
                &cfg,
                counters.clone(),
                &ExecutorConfig::default(),
            )
            .unwrap();
            let store = Arc::new(store);
            std::thread::scope(|scope| {
                for t in 0..4u64 {
                    let store = store.clone();
                    scope.spawn(move || {
                        let mut rng = Xoshiro256::new(70 + t);
                        for _ in 0..6 {
                            store
                                .try_insert_batch((0..2).map(|_| sk(&mut rng, 64)).collect())
                                .unwrap();
                        }
                    });
                }
            });
            assert_eq!(store.len(), 4 * 6 * 2);
            assert!(
                counters.group_commits.load(Ordering::Relaxed) >= 1,
                "group-commit thread never flushed a window"
            );
            store.snapshot_ordered()
        };
        // every acked (try_insert_batch returned Ok) insert is recoverable
        let (recovered, _) = ShardedStore::open_durable(
            fp(2, 64, 5),
            &IndexConfig::default(),
            &cfg,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        assert_eq!(recovered.snapshot_ordered(), expected);
    }

    #[test]
    fn wal_commit_failure_surfaces_through_try_insert_batch() {
        let dir = TempDir::new("store-commit-fail");
        // exercise both the synchronous path and the group-commit path
        for window_us in [0u64, 1_000] {
            let cfg = PersistConfig {
                commit_window_us: window_us,
                // Always so the window>0 lane actually runs group commit
                fsync: FsyncPolicy::Always,
                ..durable_cfg(&dir, PersistMode::Wal, 0)
            };
            let sub = TempDir::new(&format!("store-commit-fail-{window_us}"));
            let cfg = PersistConfig {
                data_dir: Some(sub.path().to_path_buf()),
                ..cfg
            };
            let (store, _) = ShardedStore::open_durable(
                fp(1, 64, 5),
                &IndexConfig::default(),
                &cfg,
                Arc::new(PersistCounters::default()),
                &ExecutorConfig::default(),
            )
            .unwrap();
            let mut rng = Xoshiro256::new(80);
            // a clean insert first, so the failure below is unambiguous
            store.try_insert_batch(vec![sk(&mut rng, 64)]).unwrap();
            let p = store.persistence().unwrap();
            p.wal_guard(0).fail_next_commit("injected disk failure");
            let insert = store.try_insert_batch(vec![sk(&mut rng, 64)]);
            let err = insert.unwrap_err().to_string();
            assert!(err.contains("not acknowledged as durable"), "window={window_us}: {err}");
            // the injection is one-shot: the WAL writer retries its still-
            // pending frames on the next commit and the store recovers
            store.try_insert_batch(vec![sk(&mut rng, 64)]).unwrap();
            // rows were placed in memory despite the failed ack
            assert_eq!(store.len(), 3, "window={window_us}");
        }
    }

    #[test]
    fn poisoned_shard_lock_recovers_instead_of_bricking() {
        // Regression: every shard access used read()/write().unwrap(), so
        // one panicking worker (here: a dimension-mismatched sketch hitting
        // the arena's push assert while the shard write lock and the id
        // index write lock were held) poisoned the locks and every
        // subsequent request killed the coordinator.
        let store = ShardedStore::new(2, 64);
        let mut rng = Xoshiro256::new(30);
        let ids = store.insert_batch(vec![sk(&mut rng, 64), sk(&mut rng, 64)]);
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // wrong dimension: panics inside insert_batch, under the locks
            store.insert_batch(vec![sk(&mut rng, 32)]);
        }));
        assert!(panicked.is_err(), "wrong-dim insert must still panic");
        // every read and write path must keep working on the poisoned locks
        assert!(store.get(ids[0]).is_some());
        assert!(store.pair_stats(ids[0], ids[1]).is_some());
        let more = store.insert_batch(vec![sk(&mut rng, 64)]);
        assert_eq!(store.get(more[0]).map(|s| s.len()), Some(64));
        assert_eq!(store.map_shards(|s| s.ids.len()).len(), 2);
        store.rebalance(1);
        // the aborted element's id was allocated but never placed: VACANT,
        // not a panic, and the shard arenas stayed ids == rows consistent
        let ghost = ids[1] + 1;
        assert!(store.get(ghost).is_none());
        assert!(store.locate(ghost).is_none());
        for (ids_len, rows_len) in store.map_shards(|s| (s.ids.len(), s.rows.len())) {
            assert_eq!(ids_len, rows_len);
        }
    }

    fn durable_cfg(dir: &TempDir, mode: PersistMode, snapshot_every: u64) -> PersistConfig {
        PersistConfig {
            mode,
            data_dir: Some(dir.path().to_path_buf()),
            fsync: FsyncPolicy::Never,
            snapshot_every,
            // synchronous commits: these tests pin down the non-group-commit
            // path (the group-commit tests below opt in explicitly)
            commit_window_us: 0,
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        }
    }

    fn fp(num_shards: usize, sketch_dim: usize, seed: u64) -> Fingerprint {
        Fingerprint {
            sketch_dim,
            seed,
            num_shards,
            input_dim: sketch_dim * 4,
            num_categories: 8,
        }
    }

    #[test]
    fn apply_replicated_mirrors_a_primary_log_exactly() {
        use crate::persist::wal::read_wal_tail;
        let p_dir = TempDir::new("store-repl-primary");
        let f_dir = TempDir::new("store-repl-follower");
        let cfg_p = durable_cfg(&p_dir, PersistMode::Wal, 0);
        let cfg_f = durable_cfg(&f_dir, PersistMode::Wal, 0);
        let (primary, _) = ShardedStore::open_durable(
            fp(2, 128, 9),
            &on_cfg(),
            &cfg_p,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        let mut rng = Xoshiro256::new(60);
        // one big batch lands on one shard, then rebalance emits moves —
        // the follower must replay inserts AND MoveOut/MoveIn pairs
        primary.insert_batch((0..24).map(|_| sk(&mut rng, 128)).collect());
        primary.insert_batch((0..4).map(|_| sk(&mut rng, 128)).collect());
        assert!(primary.rebalance(1) > 0);
        // the full mutation vocabulary rides the same stream: deletes
        // (head / middle / tail ids), an in-place upsert, a TTL'd
        // insert, and a deleted id resurrected with a fresh deadline
        primary.delete(0).unwrap();
        primary.delete(13).unwrap();
        primary.delete(27).unwrap();
        primary.upsert(5, sk(&mut rng, 128), 0).unwrap();
        let (res, ticket) = primary.begin_mutation_batch(vec![MutationOp::Insert {
            sketch: sk(&mut rng, 128),
            deadline: 7_777,
        }]);
        primary.finish_mutation_batch(ticket).unwrap();
        assert_eq!(res, vec![MutationResult::Inserted { id: 28 }]);
        primary.upsert(13, sk(&mut rng, 128), 1_234).unwrap();
        let (follower, _) = ShardedStore::open_durable(
            fp(2, 128, 9),
            &on_cfg(),
            &cfg_f,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        let wpr = 128usize.div_ceil(64);
        for si in 0..2 {
            let path = crate::persist::manifest::wal_path(p_dir.path(), 0, si);
            // ship in two chunks to exercise sequenced application
            let total = read_wal_tail(&path, wpr, 0, usize::MAX, u64::MAX, None)
                .unwrap()
                .file_frames;
            let mut at = 0u64;
            while at < total {
                let chunk = read_wal_tail(&path, wpr, at, 400, u64::MAX, None).unwrap();
                assert!(chunk.frames > 0);
                let replay = crate::persist::wal::scan_frames(&chunk.bytes, wpr);
                assert!(!replay.truncated);
                follower.apply_replicated(si, &chunk.bytes, &replay.records).unwrap();
                at += chunk.frames;
            }
            assert_eq!(follower.persistence().unwrap().next_seq(si), total);
        }
        // bit-identical corpus, shard layout, TTL deadlines, and O(1)
        // lookups
        assert_eq!(follower.snapshot_ordered(), primary.snapshot_ordered());
        assert_eq!(follower.shard_sizes(), primary.shard_sizes());
        assert_eq!(follower.len(), primary.len());
        let columns = |s: &ShardedStore| {
            s.map_shards(|sh| {
                sh.ids
                    .iter()
                    .copied()
                    .zip(sh.expiry.iter().copied())
                    .collect::<Vec<_>>()
            })
        };
        assert_eq!(columns(&follower), columns(&primary));
        for id in 0..primary.len() {
            assert_eq!(follower.get(id), primary.get(id), "id {id}");
            assert_eq!(follower.locate(id), primary.locate(id), "id {id}");
        }
        // the follower's own WAL is byte-identical: a restart recovers it
        drop(follower);
        let (reopened, report) = ShardedStore::open_durable(
            fp(2, 128, 9),
            &on_cfg(),
            &cfg_f,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        assert_eq!(report.replayed_records as u64, {
            let p = primary.persistence().unwrap();
            p.next_seq(0) + p.next_seq(1)
        });
        assert_eq!(reopened.snapshot_ordered(), primary.snapshot_ordered());
    }

    #[test]
    fn apply_replicated_rejects_divergent_chunks_without_mutating() {
        let dir = TempDir::new("store-repl-diverge");
        let (store, _) = ShardedStore::open_durable(
            fp(1, 64, 5),
            &IndexConfig::default(),
            &durable_cfg(&dir, PersistMode::Wal, 0),
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        let mut rng = Xoshiro256::new(61);
        let row = sk(&mut rng, 64);
        let records = vec![
            WalRecord::Insert {
                id: 0,
                deadline: 0,
                words: row.words().to_vec(),
            },
            WalRecord::MoveOut { move_id: 1 },
            WalRecord::MoveOut { move_id: 2 }, // one pop too many
        ];
        let err = store.apply_replicated(0, &[], &records).unwrap_err();
        assert!(err.to_string().contains("diverged"), "{err:#}");
        // rejected before any mutation: the shard is untouched
        assert_eq!(store.shard_sizes(), vec![0]);
        assert_eq!(store.persistence().unwrap().next_seq(0), 0);
        // a Delete (or Upsert) of an id the shard does not hold is the
        // same divergence signal, rejected just as atomically
        for bad in [
            WalRecord::Delete { id: 33 },
            WalRecord::Upsert {
                id: 33,
                deadline: 0,
                words: row.words().to_vec(),
            },
        ] {
            let records = vec![
                WalRecord::Insert {
                    id: 0,
                    deadline: 0,
                    words: row.words().to_vec(),
                },
                bad,
            ];
            let err = store.apply_replicated(0, &[], &records).unwrap_err();
            assert!(err.to_string().contains("id 33"), "{err:#}");
            assert!(err.to_string().contains("diverged"), "{err:#}");
            assert_eq!(store.shard_sizes(), vec![0]);
            assert_eq!(store.persistence().unwrap().next_seq(0), 0);
        }
    }

    #[test]
    fn begin_finish_split_matches_the_inline_path() {
        // in-memory: the ticket is trivially settled
        let store = ShardedStore::new(2, 64);
        let mut rng = Xoshiro256::new(62);
        let (ids, ticket) = store.begin_insert_batch(vec![sk(&mut rng, 64), sk(&mut rng, 64)]);
        assert_eq!(ids, vec![0, 1]);
        store.finish_insert_batch(ticket).unwrap();
        let (ids, ticket) = store.begin_insert_batch(Vec::new());
        assert!(ids.is_empty());
        store.finish_insert_batch(ticket).unwrap();
        // durable, synchronous commits: a commit fault surfaces at finish
        let dir = TempDir::new("store-begin-finish");
        let (store, _) = ShardedStore::open_durable(
            fp(1, 64, 5),
            &IndexConfig::default(),
            &durable_cfg(&dir, PersistMode::Wal, 0),
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        store.persistence().unwrap().wal_guard(0).fail_next_commit("split fault");
        let (ids, ticket) = store.begin_insert_batch(vec![sk(&mut rng, 64)]);
        assert_eq!(ids, vec![0]);
        let err = store.finish_insert_batch(ticket).unwrap_err();
        assert!(err.to_string().contains("split fault"), "{err:#}");
        // the frames stayed pending; the next batch's commit lands both
        let (_, ticket) = store.begin_insert_batch(vec![sk(&mut rng, 64)]);
        store.finish_insert_batch(ticket).unwrap();
        assert_eq!(store.persistence().unwrap().committed_seq(0), 2);
    }

    #[test]
    fn wal_max_bytes_rotates_through_the_store_trigger() {
        let dir = TempDir::new("store-bytes-rotate");
        let cfg = PersistConfig {
            snapshot_every: 0, // only the size trigger may fire
            wal_max_bytes: 512,
            ..durable_cfg(&dir, PersistMode::WalSnapshot, 0)
        };
        let counters = Arc::new(PersistCounters::default());
        let (store, _) = ShardedStore::open_durable(
            fp(1, 64, 5),
            &IndexConfig::default(),
            &cfg,
            counters.clone(),
            &ExecutorConfig::default(),
        )
        .unwrap();
        let mut rng = Xoshiro256::new(63);
        // 29-byte frames: ~18 inserts cross 512 live bytes
        for _ in 0..30 {
            store.insert_batch(vec![sk(&mut rng, 64)]);
        }
        assert!(
            counters.snapshots.load(Ordering::Relaxed) >= 1,
            "size trigger never rotated"
        );
        assert!(store.persistence().unwrap().generation() >= 1);
        // everything still recoverable after the rotation(s)
        let before = store.snapshot_ordered();
        drop(store);
        let (back, _) = ShardedStore::open_durable(
            fp(1, 64, 5),
            &IndexConfig::default(),
            &cfg,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        assert_eq!(back.snapshot_ordered(), before);
    }

    #[test]
    fn durable_store_roundtrips_across_reopen() {
        let dir = TempDir::new("store-durable");
        let cfg = durable_cfg(&dir, PersistMode::Wal, 0);
        let counters = Arc::new(PersistCounters::default());
        let mut rng = Xoshiro256::new(40);
        let pts: Vec<BitVec> = (0..18).map(|_| sk(&mut rng, 128)).collect();
        let before = {
            let (store, report) = ShardedStore::open_durable(
                fp(3, 128, 9),
                &IndexConfig::default(),
                &cfg,
                counters.clone(),
                &ExecutorConfig::default(),
            )
            .unwrap();
            assert_eq!(report.generation, 0);
            for p in pts.chunks(4) {
                store.insert_batch(p.to_vec());
            }
            assert_eq!(counters.wal_records.load(Ordering::Relaxed), 18);
            assert!(counters.wal_bytes.load(Ordering::Relaxed) > 0);
            (store.snapshot_ordered(), store.shard_sizes())
        };
        let (store, report) = ShardedStore::open_durable(
            fp(3, 128, 9),
            &IndexConfig::default(),
            &cfg,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        assert_eq!(report.replayed_records, 18);
        assert_eq!(store.len(), 18);
        assert_eq!(store.snapshot_ordered(), before.0);
        // per-shard WAL replay reproduces the exact shard layout
        assert_eq!(store.shard_sizes(), before.1);
        // new inserts continue from the recovered id space
        let new_ids = store.insert_batch(vec![sk(&mut rng, 128)]);
        assert_eq!(new_ids, vec![18]);
    }

    #[test]
    fn fingerprint_mismatch_refuses_to_open() {
        let dir = TempDir::new("store-fp");
        let cfg = durable_cfg(&dir, PersistMode::Wal, 0);
        let open = |fingerprint| {
            ShardedStore::open_durable(
                fingerprint,
                &IndexConfig::default(),
                &cfg,
                Arc::new(PersistCounters::default()),
                &ExecutorConfig::default(),
            )
        };
        open(fp(2, 64, 7)).unwrap();
        let err = open(fp(2, 128, 7)).unwrap_err().to_string();
        assert!(err.contains("sketch_dim"), "{err}");
        let err = open(fp(4, 64, 7)).unwrap_err().to_string();
        assert!(err.contains("num_shards"), "{err}");
        let err = open(fp(2, 64, 8)).unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
        // the extended fingerprint: corpus-shape drift under an identical
        // seed is a hard error too, not silent corruption at query time
        let err = open(Fingerprint {
            input_dim: 999,
            ..fp(2, 64, 7)
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("input_dim"), "{err}");
        let err = open(Fingerprint {
            num_categories: 5,
            ..fp(2, 64, 7)
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("num_categories"), "{err}");
    }

    #[test]
    #[cfg(unix)]
    fn failed_auto_snapshot_defers_instead_of_retrying_every_batch() {
        use std::os::unix::fs::PermissionsExt;
        let dir = TempDir::new("store-snap-fail");
        let cfg = durable_cfg(&dir, PersistMode::WalSnapshot, 4);
        let counters = Arc::new(PersistCounters::default());
        let (store, _) = ShardedStore::open_durable(
            fp(1, 64, 3),
            &IndexConfig::default(),
            &cfg,
            counters.clone(),
            &ExecutorConfig::default(),
        )
        .unwrap();
        let mut rng = Xoshiro256::new(50);
        // make the data dir unwritable: WAL appends still go to the open
        // fds, but the rotation cannot create its snapshot/segment files
        let perms = |mode: u32| {
            let mut p = std::fs::metadata(dir.path()).unwrap().permissions();
            p.set_mode(mode);
            std::fs::set_permissions(dir.path(), p).unwrap();
        };
        perms(0o555);
        // root bypasses directory permissions (CAP_DAC_OVERRIDE) — the
        // failure cannot be simulated there, so skip rather than flake
        if std::fs::File::create(dir.path().join("probe")).is_ok() {
            let _ = std::fs::remove_file(dir.path().join("probe"));
            perms(0o755);
            return;
        }
        store.insert_batch((0..4).map(|_| sk(&mut rng, 64)).collect());
        // the threshold was crossed, the attempt failed, and the trigger
        // was deferred — the next batch must not re-attempt immediately
        assert_eq!(counters.snapshots.load(Ordering::Relaxed), 0);
        assert!(!store.persistence().unwrap().should_auto_snapshot());
        store.insert_batch(vec![sk(&mut rng, 64)]);
        assert_eq!(counters.snapshots.load(Ordering::Relaxed), 0);
        // once the disk recovers, the next threshold crossing rotates
        perms(0o755);
        store.insert_batch((0..3).map(|_| sk(&mut rng, 64)).collect());
        assert_eq!(counters.snapshots.load(Ordering::Relaxed), 1);
        assert_eq!(store.persistence().unwrap().generation(), 1);
    }

    #[test]
    fn auto_snapshot_rotates_and_recovers() {
        let dir = TempDir::new("store-auto-snap");
        let cfg = durable_cfg(&dir, PersistMode::WalSnapshot, 8);
        let counters = Arc::new(PersistCounters::default());
        let mut rng = Xoshiro256::new(41);
        let before = {
            let (store, _) = ShardedStore::open_durable(
                fp(2, 64, 3),
                &IndexConfig::default(),
                &cfg,
                counters.clone(),
                &ExecutorConfig::default(),
            )
            .unwrap();
            for _ in 0..5 {
                store.insert_batch((0..4).map(|_| sk(&mut rng, 64)).collect());
            }
            assert!(
                counters.snapshots.load(Ordering::Relaxed) >= 1,
                "20 records at snapshot_every=8 must have rotated"
            );
            assert_eq!(
                store.persistence().unwrap().generation(),
                counters.generation.load(Ordering::Relaxed)
            );
            store.snapshot_ordered()
        };
        let (store, report) = ShardedStore::open_durable(
            fp(2, 64, 3),
            &IndexConfig::default(),
            &cfg,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        assert!(report.generation >= 1);
        assert!(report.snapshot_rows > 0, "recovery must use the snapshot");
        assert_eq!(store.snapshot_ordered(), before);
    }

    #[test]
    fn delete_removes_the_row_everywhere() {
        let store = ShardedStore::with_index(2, 128, &on_cfg(), 7);
        let mut rng = Xoshiro256::new(23);
        let pts: Vec<BitVec> = (0..12).map(|_| sk(&mut rng, 128)).collect();
        // one batch → one shard, rows in id order: ids[2] exercises the
        // swap-remove middle path (the trailing row re-homes into the
        // hole), then ids[10] sits on the last row — the fast path
        let ids = store.insert_batch(pts.clone());
        store.delete(ids[2]).unwrap();
        store.delete(ids[10]).unwrap();
        assert!(store.get(ids[2]).is_none());
        assert!(store.locate(ids[10]).is_none());
        assert!(store.pair_stats(ids[2], ids[3]).is_none());
        assert_eq!(store.live_len(), 10);
        assert_eq!(store.len(), 12, "the id space never shrinks");
        // double delete and an unknown id are described errors
        let err = store.delete(ids[2]).unwrap_err().to_string();
        assert!(err.contains("does not hold"), "{err}");
        assert!(store.delete(999).is_err());
        let gone = [ids[2], ids[10]];
        for (id, pt) in ids.iter().zip(&pts) {
            if gone.contains(id) {
                continue;
            }
            // every survivor still resolves through the O(1) index...
            assert_eq!(store.get(*id).as_ref(), Some(pt), "id {id}");
            let (s, r) = store.locate(*id).unwrap();
            let shard_ids = store.map_shards(|sh| sh.ids.clone());
            assert_eq!(shard_ids[s][r], *id);
            // ...and through its shard's LSH index, post-re-key
            let found = store.map_shards(|sh| {
                sh.index
                    .as_ref()
                    .map(|ix| ix.candidates(pt.words()).0)
                    .unwrap_or_default()
            });
            assert!(
                found[s].binary_search(&(r as u32)).is_ok(),
                "id {id} lost from the LSH index"
            );
        }
        for (rows, ix_len, exp_len) in store.map_shards(|s| {
            (s.ids.len(), s.index.as_ref().map(|ix| ix.len()), s.expiry.len())
        }) {
            assert_eq!(ix_len, Some(rows), "LSH index out of step with arena");
            assert_eq!(exp_len, rows, "expiry column out of step with arena");
        }
    }

    #[test]
    fn upsert_overwrites_in_place_and_resurrects_deleted_ids() {
        let store = ShardedStore::with_index(2, 128, &on_cfg(), 7);
        let mut rng = Xoshiro256::new(24);
        let pts: Vec<BitVec> = (0..8).map(|_| sk(&mut rng, 128)).collect();
        let ids = store.insert_batch(pts.clone());
        // in place: same shard, same row, new bits, LSH re-keyed
        let before = store.locate(ids[3]).unwrap();
        let fresh = sk(&mut rng, 128);
        store.upsert(ids[3], fresh.clone(), 0).unwrap();
        assert_eq!(
            store.locate(ids[3]).unwrap(),
            before,
            "in-place upsert moved the row"
        );
        assert_eq!(store.get(ids[3]).unwrap(), fresh);
        let (s, r) = before;
        let found = store.map_shards(|sh| {
            sh.index
                .as_ref()
                .map(|ix| ix.candidates(fresh.words()).0)
                .unwrap_or_default()
        });
        assert!(found[s].binary_search(&(r as u32)).is_ok());
        // the cached weight follows the new bits
        let (w, _, _) = store.pair_stats(ids[3], ids[0]).unwrap();
        assert_eq!(w, fresh.count_ones());
        // resurrection: delete, then upsert the same id back in
        store.delete(ids[5]).unwrap();
        assert!(store.get(ids[5]).is_none());
        let back = sk(&mut rng, 128);
        store.upsert(ids[5], back.clone(), 0).unwrap();
        assert_eq!(store.get(ids[5]).unwrap(), back);
        assert_eq!(store.live_len(), 8);
        // an id no insert ever assigned is refused
        let err = store.upsert(99, sk(&mut rng, 128), 0).unwrap_err();
        assert!(err.to_string().contains("never assigned"), "{err:#}");
    }

    #[test]
    fn sweep_expired_honors_deadlines_and_upsert_extensions() {
        let store = ShardedStore::new(2, 64);
        let mut rng = Xoshiro256::new(25);
        let ops = (0..6)
            .map(|i| MutationOp::Insert {
                sketch: sk(&mut rng, 64),
                deadline: match i {
                    0 | 1 => 1_000, // expired by t=2000
                    2 => 5_000,     // still alive at t=2000
                    _ => 0,         // no TTL
                },
            })
            .collect();
        let (results, ticket) = store.begin_mutation_batch(ops);
        store.finish_mutation_batch(ticket).unwrap();
        assert!(results
            .iter()
            .all(|r| matches!(r, MutationResult::Inserted { .. })));
        // extending id 1's deadline before the sweep rescues it: the
        // sweep re-checks under the shard lock, not just at scan time
        store.upsert(1, sk(&mut rng, 64), 9_000).unwrap();
        assert_eq!(store.sweep_expired(2_000), 1);
        assert!(store.get(0).is_none());
        assert!(store.get(1).is_some());
        assert!(store.get(2).is_some());
        assert_eq!(store.sweep_expired(2_000), 0, "a second sweep finds nothing");
        assert_eq!(store.sweep_expired(10_000), 2);
        assert_eq!(store.live_len(), 3);
    }

    #[test]
    fn durable_mutations_roundtrip_across_reopen_and_rotation() {
        let dir = TempDir::new("store-mut-durable");
        let cfg = durable_cfg(&dir, PersistMode::WalSnapshot, 0);
        let mut rng = Xoshiro256::new(42);
        let columns = |s: &ShardedStore| {
            let mut all: Vec<(usize, u64)> = s
                .map_shards(|sh| {
                    sh.ids
                        .iter()
                        .copied()
                        .zip(sh.expiry.iter().copied())
                        .collect::<Vec<_>>()
                })
                .into_iter()
                .flatten()
                .collect();
            all.sort_unstable();
            all
        };
        let open = || {
            ShardedStore::open_durable(
                fp(2, 64, 5),
                &IndexConfig::default(),
                &cfg,
                Arc::new(PersistCounters::default()),
                &ExecutorConfig::default(),
            )
        };
        let (before, before_cols) = {
            let (store, _) = open().unwrap();
            store.insert_batch((0..10).map(|_| sk(&mut rng, 64)).collect());
            let (res, ticket) = store.begin_mutation_batch(vec![
                MutationOp::Insert {
                    sketch: sk(&mut rng, 64),
                    deadline: 9_999,
                },
                MutationOp::Delete { id: 3 },
                MutationOp::Upsert {
                    id: 7,
                    sketch: sk(&mut rng, 64),
                    deadline: 1_234,
                },
                MutationOp::Delete { id: 44 }, // fails; the rest still lands
            ]);
            store.finish_mutation_batch(ticket).unwrap();
            assert_eq!(res[0], MutationResult::Inserted { id: 10 });
            assert_eq!(res[1], MutationResult::Deleted { id: 3 });
            assert_eq!(res[2], MutationResult::Upserted { id: 7 });
            assert!(matches!(res[3], MutationResult::Failed { .. }));
            store.upsert(3, sk(&mut rng, 64), 0).unwrap(); // resurrect
            (store.snapshot_ordered(), columns(&store))
        };
        // WAL replay rebuilds the exact survivor set, deadlines included
        let (back, _) = open().unwrap();
        assert_eq!(back.snapshot_ordered(), before);
        assert_eq!(columns(&back), before_cols);
        // a rotation folds the mutations into the snapshot; recovery
        // from it (empty tail) must agree byte-for-byte — the
        // post-compaction == pre-compaction recovery contract
        back.persist_snapshot().unwrap();
        drop(back);
        let (again, report) = open().unwrap();
        assert!(report.snapshot_rows > 0);
        assert_eq!(report.replayed_records, 0, "the tail must be empty");
        assert_eq!(again.snapshot_ordered(), before);
        assert_eq!(columns(&again), before_cols);
        // the id space continues past every assigned id, deleted or not
        assert_eq!(again.insert_batch(vec![sk(&mut rng, 64)]), vec![11]);
    }

    #[test]
    fn dead_frame_threshold_folds_compaction_into_rotation() {
        let dir = TempDir::new("store-compact");
        let cfg = PersistConfig {
            compact_dead_frames: 4,
            ..durable_cfg(&dir, PersistMode::WalSnapshot, 0)
        };
        let counters = Arc::new(PersistCounters::default());
        let (store, _) = ShardedStore::open_durable(
            fp(1, 64, 5),
            &IndexConfig::default(),
            &cfg,
            counters.clone(),
            &ExecutorConfig::default(),
        )
        .unwrap();
        let mut rng = Xoshiro256::new(43);
        store.insert_batch((0..6).map(|_| sk(&mut rng, 64)).collect());
        // two deletes = 4 dead frames (each kills its insert and itself):
        // the threshold crossing rotates on the second delete's settle
        store.delete(0).unwrap();
        assert_eq!(counters.snapshots.load(Ordering::Relaxed), 0);
        store.delete(1).unwrap();
        assert!(
            counters.snapshots.load(Ordering::Relaxed) >= 1,
            "dead-frame trigger never rotated"
        );
        assert_eq!(counters.compactions.load(Ordering::Relaxed), 1);
        assert_eq!(
            counters.wal_dead_frames.load(Ordering::Relaxed),
            0,
            "rotation must reset the dead-frame gauge"
        );
        // the rotated snapshot holds only survivors; recovery agrees
        let before = store.snapshot_ordered();
        assert_eq!(before.len(), 4);
        drop(store);
        let (back, report) = ShardedStore::open_durable(
            fp(1, 64, 5),
            &IndexConfig::default(),
            &cfg,
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap();
        assert_eq!(report.snapshot_rows, 4);
        assert_eq!(back.snapshot_ordered(), before);
    }

    #[test]
    fn rebalance_move_ids_pair_and_reseed_after_reopen() {
        use crate::persist::wal::{read_wal_tail, scan_frames};
        let dir = TempDir::new("store-move-ids");
        let cfg = durable_cfg(&dir, PersistMode::Wal, 0);
        let mut rng = Xoshiro256::new(44);
        let move_ids = |si: usize, outs: bool| -> Vec<u64> {
            let path = crate::persist::manifest::wal_path(dir.path(), 0, si);
            let tail = read_wal_tail(&path, 1, 0, usize::MAX, u64::MAX, None).unwrap();
            scan_frames(&tail.bytes, 1)
                .records
                .iter()
                .filter_map(|r| match r {
                    WalRecord::MoveOut { move_id } if outs => Some(*move_id),
                    WalRecord::MoveIn { move_id, .. } if !outs => Some(*move_id),
                    _ => None,
                })
                .collect()
        };
        let open = || {
            ShardedStore::open_durable(
                fp(2, 64, 5),
                &IndexConfig::default(),
                &cfg,
                Arc::new(PersistCounters::default()),
                &ExecutorConfig::default(),
            )
        };
        let first_max = {
            let (store, _) = open().unwrap();
            store.insert_batch((0..16).map(|_| sk(&mut rng, 64)).collect());
            assert!(store.rebalance(1) > 0);
            // every MoveOut pairs with exactly one MoveIn stamped with
            // the same move id, in the other shard's log
            let mut outs: Vec<u64> = (0..2).flat_map(|si| move_ids(si, true)).collect();
            let mut ins: Vec<u64> = (0..2).flat_map(|si| move_ids(si, false)).collect();
            outs.sort_unstable();
            ins.sort_unstable();
            assert!(!outs.is_empty());
            assert_eq!(outs, ins);
            *outs.last().unwrap()
        };
        // reopen: recovery reports the replayed maximum and the counter
        // reseeds past it, so no move id is ever reused
        let (store, report) = open().unwrap();
        assert_eq!(report.max_move_id, first_max);
        store.insert_batch((0..20).map(|_| sk(&mut rng, 64)).collect());
        assert!(store.rebalance(1) > 0);
        let mut outs: Vec<u64> = (0..2).flat_map(|si| move_ids(si, true)).collect();
        outs.sort_unstable();
        let n = outs.len();
        outs.dedup();
        assert_eq!(outs.len(), n, "a move id was reused after reopen");
        assert!(*outs.last().unwrap() > first_max);
    }
}
