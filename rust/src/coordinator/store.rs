//! Sharded in-memory sketch store.
//!
//! Sketches are spread across `S` shards. Placement is *least-loaded*
//! (size-balanced) so scatter/gather query work divides evenly; ids are
//! global and never reused. Each shard keeps the packed sketches
//! contiguously for cache-friendly scans.

use crate::sketch::BitVec;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

pub struct Shard {
    pub ids: Vec<usize>,
    pub sketches: Vec<BitVec>,
}

pub struct ShardedStore {
    shards: Vec<RwLock<Shard>>,
    next_id: AtomicUsize,
    sketch_dim: usize,
}

impl ShardedStore {
    pub fn new(num_shards: usize, sketch_dim: usize) -> Self {
        Self {
            shards: (0..num_shards.max(1))
                .map(|_| {
                    RwLock::new(Shard {
                        ids: Vec::new(),
                        sketches: Vec::new(),
                    })
                })
                .collect(),
            next_id: AtomicUsize::new(0),
            sketch_dim,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn sketch_dim(&self) -> usize {
        self.sketch_dim
    }

    pub fn len(&self) -> usize {
        self.next_id.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a batch of sketches; returns their assigned global ids.
    /// The whole batch lands on the currently least-loaded shard (cheap
    /// balancing with batch locality).
    pub fn insert_batch(&self, sketches: Vec<BitVec>) -> Vec<usize> {
        let k = sketches.len();
        let ids: Vec<usize> = (0..k)
            .map(|_| self.next_id.fetch_add(1, Ordering::Relaxed))
            .collect();
        let target = self
            .shards
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| s.read().unwrap().ids.len())
            .map(|(i, _)| i)
            .unwrap_or(0);
        let mut shard = self.shards[target].write().unwrap();
        shard.ids.extend_from_slice(&ids);
        shard.sketches.extend(sketches);
        ids
    }

    /// Fetch a sketch by global id (linear over shards, binary-search-free:
    /// ids within a shard are appended in order but batches interleave, so
    /// we scan — distance lookups are rare relative to queries).
    pub fn get(&self, id: usize) -> Option<BitVec> {
        for shard in &self.shards {
            let s = shard.read().unwrap();
            if let Some(pos) = s.ids.iter().position(|&x| x == id) {
                return Some(s.sketches[pos].clone());
            }
        }
        None
    }

    /// Run `f` over every shard (read-locked) and collect results.
    pub fn map_shards<T, F: Fn(&Shard) -> T>(&self, f: F) -> Vec<T> {
        self.shards
            .iter()
            .map(|s| f(&s.read().unwrap()))
            .collect()
    }

    /// Parallel scatter over shards with per-shard worker threads.
    pub fn par_map_shards<T: Send, F: Fn(&Shard) -> T + Sync>(&self, f: F) -> Vec<T> {
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|s| {
                    let f = &f;
                    scope.spawn(move || f(&s.read().unwrap()))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    /// All sketches in id order (testing/heatmaps on small corpora).
    pub fn snapshot_ordered(&self) -> Vec<(usize, BitVec)> {
        let mut all: Vec<(usize, BitVec)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let s = shard.read().unwrap();
            all.extend(s.ids.iter().copied().zip(s.sketches.iter().cloned()));
        }
        all.sort_by_key(|&(id, _)| id);
        all
    }

    /// Shard occupancy (balance diagnostics / rebalance tests).
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.map_shards(|s| s.ids.len())
    }

    /// Rebalance: move whole trailing runs from over-full to under-full
    /// shards until max-min ≤ tolerance. Returns number of moved sketches.
    pub fn rebalance(&self, tolerance: usize) -> usize {
        let mut moved = 0;
        loop {
            let sizes = self.shard_sizes();
            let (max_i, &max_v) = sizes
                .iter()
                .enumerate()
                .max_by_key(|&(_, v)| *v)
                .unwrap();
            let (min_i, &min_v) = sizes
                .iter()
                .enumerate()
                .min_by_key(|&(_, v)| *v)
                .unwrap();
            if max_v <= min_v + tolerance.max(1) {
                return moved;
            }
            let take = (max_v - min_v) / 2;
            // lock ordering by index avoids deadlock
            let (lo, hi) = (max_i.min(min_i), max_i.max(min_i));
            let (first, second) = (self.shards[lo].write().unwrap(), self.shards[hi].write().unwrap());
            let (mut src, mut dst) = if max_i == lo { (first, second) } else { (second, first) };
            for _ in 0..take {
                if let (Some(id), Some(sk)) = (src.ids.pop(), src.sketches.pop()) {
                    dst.ids.push(id);
                    dst.sketches.push(sk);
                    moved += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn sk(rng: &mut Xoshiro256, d: usize) -> BitVec {
        BitVec::from_indices(d, rng.sample_indices(d, d / 8))
    }

    #[test]
    fn ids_are_unique_and_dense() {
        let store = ShardedStore::new(4, 64);
        let mut rng = Xoshiro256::new(1);
        let mut all_ids = Vec::new();
        for _ in 0..10 {
            let batch: Vec<BitVec> = (0..5).map(|_| sk(&mut rng, 64)).collect();
            all_ids.extend(store.insert_batch(batch));
        }
        all_ids.sort_unstable();
        assert_eq!(all_ids, (0..50).collect::<Vec<_>>());
        assert_eq!(store.len(), 50);
    }

    #[test]
    fn get_retrieves_inserted() {
        let store = ShardedStore::new(3, 32);
        let mut rng = Xoshiro256::new(2);
        let a = sk(&mut rng, 32);
        let b = sk(&mut rng, 32);
        let ids = store.insert_batch(vec![a.clone(), b.clone()]);
        assert_eq!(store.get(ids[0]).unwrap(), a);
        assert_eq!(store.get(ids[1]).unwrap(), b);
        assert!(store.get(999).is_none());
    }

    #[test]
    fn balancing_across_shards() {
        let store = ShardedStore::new(4, 16);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..16 {
            store.insert_batch(vec![sk(&mut rng, 16)]);
        }
        let sizes = store.shard_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 16);
        assert!(sizes.iter().all(|&s| s == 4), "{sizes:?}");
    }

    #[test]
    fn rebalance_conserves_and_levels() {
        let store = ShardedStore::new(2, 16);
        let mut rng = Xoshiro256::new(4);
        // imbalance: one big batch to one shard
        store.insert_batch((0..20).map(|_| sk(&mut rng, 16)).collect());
        let before: usize = store.shard_sizes().iter().sum();
        let moved = store.rebalance(1);
        let after = store.shard_sizes();
        assert_eq!(after.iter().sum::<usize>(), before);
        assert!(moved > 0);
        assert!((after[0] as i64 - after[1] as i64).abs() <= 2, "{after:?}");
        // everything still retrievable
        let snap = store.snapshot_ordered();
        assert_eq!(snap.len(), 20);
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn par_map_matches_map() {
        let store = ShardedStore::new(4, 16);
        let mut rng = Xoshiro256::new(5);
        for _ in 0..8 {
            store.insert_batch(vec![sk(&mut rng, 16)]);
        }
        let a = store.map_shards(|s| s.ids.len());
        let b = store.par_map_shards(|s| s.ids.len());
        assert_eq!(a, b);
    }
}
