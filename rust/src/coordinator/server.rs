//! The coordinator service: TCP accept loop, per-connection threads,
//! request dispatch to batcher/router/store.
//!
//! Observability: `serve` initialises the leveled logger
//! (`--log-level`, `--log-json`) and the global slow-op threshold
//! (`--slow-op-ms`) once at startup, attaches the shared
//! [`crate::obs::Stages`] histograms to the store, and stamps every
//! connection's requests with a trace id (`conn * 1e6 + seq`) that rides
//! batcher tickets so slow-op records correlate across threads. Queries
//! additionally carry a per-request [`crate::obs::ReadSpan`] whose
//! critical-path breakdown lands in the `server/slow_op` record. A
//! wire-supplied `"trace"` field overrides the stamped id, so one id can
//! follow a request across nodes (client → follower redirect → primary,
//! or primary → replication pull); lifecycle transitions additionally
//! land in the [`crate::obs::journal`] flight recorder. Stream ops
//! (`repl_snapshot`, `repl_wal_tail`, `metrics_text`, `events`) — whose
//! replies are a JSON header line + raw payload bytes — are parsed as
//! one [`StreamRequest`] envelope before request parsing and dispatched
//! through a single `handle_stream` routing point.

use super::batcher::{Batcher, BatcherConfig, SketchBackend, WriteOp};
use super::executor::ExecutorConfig;
use super::metrics::Metrics;
use super::protocol::{Request, Response, StreamRequest, WriteOpts};
use super::router;
use super::store::ShardedStore;
use crate::index::IndexConfig;
use crate::obs::{self, log as obs_log, ReadSpan};
use crate::persist::{Fingerprint, PersistConfig};
use crate::replica::{self, ReplicaConfig, ReplicaRuntime};
use crate::runtime::XlaHandle;
use crate::sketch::{CabinSketcher, SketchConfig};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Corpus configuration (must match incoming vectors).
    pub input_dim: usize,
    pub num_categories: u16,
    pub sketch_dim: usize,
    pub seed: u64,
    pub num_shards: usize,
    pub batcher: BatcherConfig,
    /// Prefer the XLA artifacts when they match (n, c, d, seed).
    pub use_xla: bool,
    /// Refuse heatmap requests above this corpus size (they are O(n²)).
    pub heatmap_limit: usize,
    /// Sublinear query path: per-shard multi-probe Hamming-LSH candidate
    /// indexes (auto / on / off, plus banding parameters).
    pub index: IndexConfig,
    /// Crash-safe persistence: per-shard WAL + periodic snapshots under a
    /// data dir (off / wal / wal+snapshot, fsync policy, auto-snapshot
    /// interval, group-commit window). Off by default — see
    /// [`crate::persist`].
    pub persist: PersistConfig,
    /// Per-shard executor work-queue bound: how many scan jobs may wait on
    /// one shard worker before submitters block (backpressure).
    pub executor_queue: usize,
    /// Replica mode (`serve --replicate-from <addr>`): bootstrap from and
    /// continuously replicate this primary, serving reads only until
    /// promoted. Requires persistence (the shipped log lives in the local
    /// data dir). `None` = ordinary writable server.
    pub replicate_from: Option<String>,
    /// Follower poll interval once caught up (`--repl-poll-ms`).
    pub repl_poll_ms: u64,
    /// Health-checked automatic failover (`--auto-promote`, replicas
    /// only): probe the primary every `probe_interval_ms`; when
    /// `probe_failures` consecutive probes miss their `probe_timeout_ms`
    /// budget, promote this replica automatically. A probe that answers
    /// within budget — however slowly the primary is otherwise serving —
    /// resets the count: slow is not dead.
    pub auto_promote: bool,
    /// Primary liveness probe interval (`--probe-interval-ms`).
    pub probe_interval_ms: u64,
    /// Per-probe answer budget (`--probe-timeout-ms`).
    pub probe_timeout_ms: u64,
    /// Consecutive budget misses before auto-promotion
    /// (`--probe-failures`).
    pub probe_failures: u32,
    /// TTL sweep interval for `serve` (`--ttl-sweep-ms`, 0 = off). The
    /// sweep runs on the primary only and deletes rows whose expiry
    /// deadline has passed, emitting ordinary replicated Delete frames;
    /// expired-but-unswept rows are still served, so the interval is the
    /// expiry granularity. Unpromoted replicas never sweep — they mirror
    /// the primary's sweep deletions from the shipped log.
    pub ttl_sweep_ms: u64,
    /// Minimum level for structured log events (`--log-level`:
    /// debug / info / warn / error).
    pub log_level: String,
    /// Emit log events as JSONL instead of human text (`--log-json`).
    pub log_json: bool,
    /// Emit one structured `slow_op` record with a per-stage breakdown
    /// for any request slower than this (`--slow-op-ms`, 0 = off).
    pub slow_op_ms: u64,
    /// Advisory read-staleness budget (`--max-read-staleness-ms`,
    /// 0 = unset). Not enforced — surfaced as the
    /// `cfg_max_read_staleness_ms` gauge next to the follower's
    /// `repl_visibility_age_ms_shard*` gauges, so one scrape says both
    /// what the operator promised and what the node is delivering.
    pub max_read_staleness_ms: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            input_dim: 4096,
            num_categories: 64,
            sketch_dim: 1024,
            seed: 42,
            num_shards: 4,
            batcher: BatcherConfig::default(),
            use_xla: true,
            heatmap_limit: 4096,
            index: IndexConfig::default(),
            persist: PersistConfig::default(),
            executor_queue: 1024,
            replicate_from: None,
            repl_poll_ms: 2,
            auto_promote: false,
            probe_interval_ms: 500,
            probe_timeout_ms: 1_000,
            probe_failures: 3,
            ttl_sweep_ms: 1_000,
            log_level: "info".into(),
            log_json: false,
            slow_op_ms: 0,
            max_read_staleness_ms: 0,
        }
    }
}

/// Wall-clock unix millis — the timebase for TTL deadlines. The wire
/// carries *relative* `ttl_ms`; only the primary calls this, so every
/// replica applies the primary's absolute deadlines and the corpus stays
/// bit-identical across clock-skewed machines.
pub(crate) fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// The running service (in-process handle). `serve` binds a TCP listener;
/// `handle_request` is also callable directly (examples, tests, benches).
pub struct Coordinator {
    pub config: CoordinatorConfig,
    pub store: Arc<ShardedStore>,
    pub metrics: Arc<Metrics>,
    batcher: Batcher,
    sketcher: CabinSketcher,
    /// Follower runtime (`--replicate-from`): gates inserts until
    /// promotion and owns the puller thread. `None` on a primary.
    replica: Option<Arc<ReplicaRuntime>>,
    /// Failover instrumentation (probe/promotion/fence counters),
    /// shared with the replica runtime's probe loop.
    failover: Arc<replica::FailoverCounters>,
    /// Epoch fence: 0 = not fenced; otherwise the higher peer epoch this
    /// server observed. Set durably (marker file + this gauge) on first
    /// contact from a newer-epoch peer; restored from the marker at
    /// startup so a fenced ex-primary comes back fenced.
    fenced: AtomicU64,
    shutdown: Arc<AtomicBool>,
    /// Connection counter backing the per-request trace ids.
    next_conn: AtomicU64,
}

impl Coordinator {
    /// Infallible construction for in-memory configurations; panics with
    /// the recovery error when persistence is enabled and the data dir
    /// cannot be recovered (use [`Coordinator::try_new`] to handle it).
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        Self::try_new(config).unwrap_or_else(|e| panic!("coordinator startup failed: {e:#}"))
    }

    /// Build the coordinator, recovering the persisted corpus (newest
    /// snapshot + WAL tail, fingerprint-checked) when `config.persist` is
    /// enabled.
    pub fn try_new(mut config: CoordinatorConfig) -> Result<Coordinator> {
        // A persistence mode without a data dir is a configuration error,
        // not a silent fall-back to in-memory: the caller asked for
        // durability and would otherwise lose the corpus on restart
        // without any hint.
        if config.persist.mode != crate::persist::PersistMode::Off
            && config.persist.data_dir.is_none()
        {
            anyhow::bail!(
                "persist mode {:?} requires a data_dir (CoordinatorConfig.persist.data_dir / \
                 --data-dir)",
                config.persist.mode
            );
        }
        // Observability first, so everything below (bootstrap, recovery)
        // already logs through the leveled logger.
        obs_log::init(
            obs_log::Level::parse(&config.log_level).unwrap_or(obs_log::Level::Info),
            config.log_json,
        );
        obs::set_slow_op_ms(config.slow_op_ms);
        // Flight recorder: from here on, lifecycle transitions land in
        // the in-process ring; a panic dumps the recent timeline to
        // stderr even when nobody was tailing the logs.
        obs::journal::install_panic_hook();
        obs::journal::record(
            "coordinator",
            "startup",
            &[
                ("shards", obs_log::V::u(config.num_shards.max(1) as u64)),
                ("replica", obs_log::V::b(config.replicate_from.is_some())),
            ],
        );
        // Scoring-kernel dispatch is decided once per process; record the
        // selected ISA at startup (also surfaced as the `kernel_isa` gauge
        // in `stats` / `metrics_text`).
        let isa = crate::sketch::kernels::active().isa;
        obs_log::info(
            "coordinator",
            "kernel_isa_selected",
            &[("isa", obs_log::V::s(isa.name().to_string()))],
        );
        // Pin the index knobs to what the shards will actually build
        // (band_bits clamps to min(64, sketch_dim), bands to ≥ 1), so the
        // `index_cfg_*` stats fields always describe the live indexes.
        config.index = config.index.normalized(config.sketch_dim);
        let metrics = Arc::new(Metrics::new());
        // the store's persistent shard workers report into the service
        // metrics (executor_* stats fields)
        let exec = ExecutorConfig {
            queue_cap: config.executor_queue,
            counters: metrics.executor.clone(),
        };
        let fingerprint = Fingerprint {
            sketch_dim: config.sketch_dim,
            seed: config.seed,
            num_shards: config.num_shards.max(1),
            input_dim: config.input_dim,
            num_categories: config.num_categories,
        };
        // Replica bootstrap runs BEFORE the store opens: it materialises
        // the primary's newest snapshot + manifest anchoring into the
        // data dir (unless one is already there — restart → resume), and
        // the ordinary recovery path below then loads it like any other
        // durable corpus.
        if let Some(primary) = &config.replicate_from {
            anyhow::ensure!(
                config.persist.enabled(),
                "--replicate-from requires persistence (--data-dir): the shipped log and \
                 snapshots live in the replica's own data dir"
            );
            let dir = config.persist.data_dir.clone().expect("enabled() implies data_dir");
            // Rejoining as an explicit follower supersedes any fence
            // marker left by a past demotion: the follower role is
            // read-only by construction, and the puller adopts the new
            // primary's (higher) epoch from the shipped headers.
            crate::persist::manifest::clear_fence(&dir)?;
            let boot = replica::bootstrap(primary, &fingerprint, &dir)
                .with_context(|| format!("bootstrapping replica from {primary}"))?;
            obs_log::info(
                "coordinator",
                "replica_bootstrap",
                &[("detail", obs_log::V::s(boot.describe()))],
            );
        }
        // A durable non-replica restarting over a fenced data dir comes
        // back fenced: the marker is the durable "a newer primary
        // superseded this server" bit, and forgetting it across a restart
        // would reopen the split-brain window the fence closed.
        let fenced = AtomicU64::new(0);
        if config.replicate_from.is_none() && config.persist.enabled() {
            let dir = config.persist.data_dir.as_deref().expect("enabled() implies data_dir");
            if let Some(epoch) = crate::persist::manifest::read_fence(dir)? {
                fenced.store(epoch, Ordering::SeqCst);
                obs_log::warn(
                    "coordinator",
                    "fence_restored",
                    &[("observed_epoch", obs_log::V::u(epoch))],
                );
                obs::journal::record(
                    "coordinator",
                    "fence_restored",
                    &[("observed_epoch", obs_log::V::u(epoch))],
                );
            }
        }
        let store = if config.persist.enabled() {
            let (store, report) = ShardedStore::open_durable(
                fingerprint,
                &config.index,
                &config.persist,
                metrics.persist.clone(),
                &exec,
            )?;
            obs_log::info(
                "coordinator",
                "recovered",
                &[
                    ("sketches", obs_log::V::u(store.len() as u64)),
                    ("generation", obs_log::V::u(report.generation)),
                    ("snapshot_rows", obs_log::V::u(report.snapshot_rows as u64)),
                    ("wal_records", obs_log::V::u(report.replayed_records as u64)),
                    ("torn_tails", obs_log::V::u(report.truncated_tails as u64)),
                    ("recovery_ms", obs_log::V::u(report.recovery_ms)),
                ],
            );
            Arc::new(store)
        } else {
            Arc::new(ShardedStore::with_runtime(
                config.num_shards,
                config.sketch_dim,
                &config.index,
                config.seed,
                &exec,
            ))
        };
        // the store records write_place/write_wal/write_fsync into the
        // same stage histograms the batcher and router use
        store.attach_stages(metrics.stages.clone());
        let sk_cfg = SketchConfig::new(
            config.input_dim,
            config.num_categories,
            config.sketch_dim,
            config.seed,
        );
        let native = CabinSketcher::from_config(sk_cfg);
        let backend = if config.use_xla {
            match XlaHandle::try_default() {
                Some(handle)
                    if handle.manifest.n == config.input_dim
                        && handle.manifest.c == config.num_categories
                        && handle.manifest.d == config.sketch_dim
                        && handle.manifest.seed == config.seed =>
                {
                    obs_log::info("coordinator", "xla_backend_active", &[]);
                    // π from the sidecar so native fallback is bit-identical
                    let native_xla = handle
                        .native_equivalent()
                        .unwrap_or_else(|_| native.clone());
                    SketchBackend::Xla(handle, native_xla)
                }
                Some(handle) => {
                    obs_log::warn(
                        "coordinator",
                        "xla_config_mismatch",
                        &[
                            ("artifact_n", obs_log::V::u(handle.manifest.n as u64)),
                            ("artifact_d", obs_log::V::u(handle.manifest.d as u64)),
                            ("artifact_seed", obs_log::V::u(handle.manifest.seed)),
                        ],
                    );
                    SketchBackend::Native(native.clone())
                }
                None => SketchBackend::Native(native.clone()),
            }
        } else {
            SketchBackend::Native(native.clone())
        };
        let sketcher = backend.sketcher().clone();
        let batcher = Batcher::start(config.batcher, backend, store.clone(), metrics.clone());
        // the puller starts only after the store recovered the
        // bootstrapped state — it resumes from the recovered applied seqs
        let failover = Arc::new(replica::FailoverCounters::default());
        let replica = config.replicate_from.as_ref().map(|primary| {
            ReplicaRuntime::start(
                store.clone(),
                ReplicaConfig {
                    primary: primary.clone(),
                    poll: Duration::from_millis(config.repl_poll_ms.max(1)),
                    auto_promote: config.auto_promote,
                    probe_interval: Duration::from_millis(config.probe_interval_ms.max(10)),
                    probe_timeout: Duration::from_millis(config.probe_timeout_ms.max(10)),
                    probe_failures: config.probe_failures.max(1),
                    ..ReplicaConfig::default()
                },
                metrics.repl.clone(),
                failover.clone(),
            )
        });
        Ok(Coordinator {
            config,
            store,
            metrics,
            batcher,
            sketcher,
            replica,
            failover,
            fenced,
            shutdown: Arc::new(AtomicBool::new(false)),
            next_conn: AtomicU64::new(0),
        })
    }

    /// Routing options for this coordinator's query path: index usage per
    /// the configured mode, traffic recorded into the service metrics
    /// (Arc-shared — the scan jobs run on the store's persistent workers).
    fn query_opts(&self) -> router::QueryOpts {
        router::QueryOpts::indexed(
            self.config.index.min_rows_for_index(),
            Some(self.metrics.index.clone()),
        )
    }

    /// This server's durable failover epoch (`None` on non-durable
    /// servers — they carry no epoch and their wire replies omit it).
    fn current_epoch(&self) -> Option<u64> {
        self.store.persistence().map(|p| p.epoch())
    }

    /// The fence rejection for a write (or shipper pull) reaching a
    /// fenced server. Names both epochs: clients parse neither, but an
    /// operator reading the error must see exactly how stale this server
    /// is.
    fn fence_error(&self, observed: u64) -> Response {
        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        Response::Error {
            message: format!(
                "write fenced: a newer primary at epoch {observed} superseded this \
                 server (own epoch {}); demote and rejoin with --replicate-from",
                self.current_epoch().unwrap_or(0)
            ),
        }
    }

    /// Record a peer-reported failover epoch. When the peer's epoch is
    /// higher than our own and this server currently holds write
    /// authority (a primary, or a promoted replica), fence: persist the
    /// marker first, then publish the in-memory gauge — a crash between
    /// the two re-fences from the marker at restart. Returns the fence
    /// rejection when this server is (now or already) fenced, `None` when
    /// the peer epoch is unremarkable. Unpromoted followers never fence —
    /// they are read-only by construction and adopt higher epochs through
    /// the puller instead.
    fn observe_epoch(&self, peer: u64) -> Option<Response> {
        let p = self.store.persistence()?;
        if self.replica.as_ref().is_some_and(|r| !r.is_writable()) {
            return None;
        }
        let own = p.epoch();
        if peer > own && self.fenced.load(Ordering::SeqCst) < peer {
            if let Err(e) = crate::persist::manifest::write_fence(p.data_dir(), peer) {
                // a fence we cannot persist still fences this process —
                // refusing writes now is strictly safer than acking them
                obs_log::error(
                    "coordinator",
                    "fence_persist_failed",
                    &[("error", obs_log::V::s(format!("{e:#}")))],
                );
            }
            self.fenced.store(peer, Ordering::SeqCst);
            self.failover.fence_events.fetch_add(1, Ordering::Relaxed);
            self.failover.last_epoch.store(peer, Ordering::Relaxed);
            obs_log::warn(
                "coordinator",
                "fenced",
                &[
                    ("own_epoch", obs_log::V::u(own)),
                    ("observed_epoch", obs_log::V::u(peer)),
                ],
            );
            obs::journal::record(
                "coordinator",
                "fence_raised",
                &[
                    ("own_epoch", obs_log::V::u(own)),
                    ("observed_epoch", obs_log::V::u(peer)),
                ],
            );
        }
        match self.fenced.load(Ordering::SeqCst) {
            0 => None,
            observed => Some(self.fence_error(observed)),
        }
    }

    /// Read-replica write gate: every mutating op is redirected to the
    /// primary until promotion; a fenced ex-primary rejects with the
    /// fence error instead. `Some(response)` means "reject with this".
    fn write_gate(&self) -> Option<Response> {
        match self.fenced.load(Ordering::SeqCst) {
            0 => {}
            observed => return Some(self.fence_error(observed)),
        }
        let r = self.replica.as_ref()?;
        if r.is_writable() {
            return None;
        }
        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        Some(Response::Error {
            message: format!(
                "read-only replica: writes go to the primary at {} \
                 (or `promote` this replica)",
                r.primary()
            ),
        })
    }

    /// Dispatch one request (thread-safe). Untraced — in-process callers
    /// (tests, examples, benches) get trace id 0, meaning "no trace".
    /// The batcher's submit handle — every mutation arm routes through
    /// its [`BatchSubmitter::submit_with`](super::batcher::BatchSubmitter::submit_with).
    fn submitter(&self) -> &super::batcher::BatchSubmitter {
        &self.batcher.submitter
    }

    pub fn handle_request(&self, req: Request) -> Response {
        self.handle_request_traced(req, 0)
    }

    /// Dispatch one request carrying a trace id. The id rides batcher
    /// tickets (write path) and tags slow-op records (both paths), so a
    /// slow request's per-stage breakdown can be correlated with its
    /// connection.
    pub fn handle_request_traced(&self, req: Request, trace: u64) -> Response {
        match req {
            Request::Ping { epoch } => {
                // a ping always answers pong — it is the liveness probe,
                // and probe semantics must not depend on fencing — but a
                // peer epoch riding on it still fences a stale server as
                // a side effect (the resilient client pings its known
                // epoch on connect, which is how a revived old primary
                // usually learns it was superseded)
                if let Some(peer) = epoch {
                    let _ = self.observe_epoch(peer);
                }
                Response::Pong {
                    epoch: self.current_epoch(),
                }
            }
            Request::Shutdown => {
                // graceful-shutdown flush: whatever reached the store is
                // fsynced before the shutdown is acknowledged (the batcher
                // drains its own queue on coordinator drop)
                if self.store.persistence().is_some() {
                    if let Err(e) = self.store.persist_flush() {
                        obs_log::error(
                            "coordinator",
                            "shutdown_flush_failed",
                            &[("error", obs_log::V::s(format!("{e:#}")))],
                        );
                    }
                }
                self.shutdown.store(true, Ordering::SeqCst);
                Response::ShuttingDown
            }
            Request::Flush => match self.store.persist_flush() {
                Ok(()) => Response::Flushed,
                Err(e) => {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    Response::Error {
                        message: format!("{e:#}"),
                    }
                }
            },
            Request::Snapshot => match self.store.persist_snapshot() {
                Ok(generation) => Response::Snapshotted { generation },
                Err(e) => {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    Response::Error {
                        message: format!("{e:#}"),
                    }
                }
            },
            Request::Insert { vec } => {
                if let Some(resp) = self.write_gate() {
                    return resp;
                }
                self.metrics.inserts.fetch_add(1, Ordering::Relaxed);
                let opts = WriteOpts { ttl_ms: 0, trace };
                match self.submitter().submit_with(WriteOp::Insert { vec }, &opts) {
                    // the ack's epoch is the term the write was accepted
                    // under — a resilient client compares it across
                    // endpoints to spot a superseded primary
                    Ok(id) => Response::Inserted {
                        id,
                        epoch: self.current_epoch(),
                    },
                    Err(e) => {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        Response::Error {
                            message: format!("{e:#}"),
                        }
                    }
                }
            }
            Request::InsertTtl { vec, ttl_ms } => {
                if let Some(resp) = self.write_gate() {
                    return resp;
                }
                self.metrics.inserts.fetch_add(1, Ordering::Relaxed);
                // the wire's relative TTL becomes an absolute deadline
                // inside submit_with, once, on the primary — the WAL and
                // every replica carry the deadline, not the TTL
                let opts = WriteOpts { ttl_ms, trace };
                match self.submitter().submit_with(WriteOp::Insert { vec }, &opts) {
                    Ok(id) => Response::Inserted {
                        id,
                        epoch: self.current_epoch(),
                    },
                    Err(e) => {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        Response::Error {
                            message: format!("{e:#}"),
                        }
                    }
                }
            }
            Request::Delete { id } => {
                if let Some(resp) = self.write_gate() {
                    return resp;
                }
                self.metrics.deletes.fetch_add(1, Ordering::Relaxed);
                let opts = WriteOpts { ttl_ms: 0, trace };
                match self.submitter().submit_with(WriteOp::Delete { id }, &opts) {
                    Ok(id) => Response::Deleted {
                        id,
                        epoch: self.current_epoch(),
                    },
                    Err(e) => {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        Response::Error {
                            message: format!("{e:#}"),
                        }
                    }
                }
            }
            Request::Upsert { id, vec, ttl_ms } => {
                if let Some(resp) = self.write_gate() {
                    return resp;
                }
                self.metrics.upserts.fetch_add(1, Ordering::Relaxed);
                // ttl_ms == 0 clears any previous deadline on the id
                let opts = WriteOpts { ttl_ms, trace };
                match self.submitter().submit_with(WriteOp::Upsert { id, vec }, &opts) {
                    Ok(id) => Response::Upserted {
                        id,
                        epoch: self.current_epoch(),
                    },
                    Err(e) => {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        Response::Error {
                            message: format!("{e:#}"),
                        }
                    }
                }
            }
            Request::Query { vec, k } => {
                let start = Instant::now();
                self.metrics.queries.fetch_add(1, Ordering::Relaxed);
                let span = Arc::new(ReadSpan::default());
                let opts = self
                    .query_opts()
                    .with_observer(self.metrics.stages.clone(), Some(Arc::clone(&span)));
                let q = self.sketcher.sketch(&vec);
                let hits = router::topk_with(&self.store, &q, k, &opts);
                let total = start.elapsed().as_secs_f64();
                self.metrics.record_query_latency(total);
                self.note_slow_read("query", trace, k, total, &span);
                Response::Hits { hits }
            }
            Request::QueryBatch { vecs, k } => {
                let start = Instant::now();
                let n = vecs.len();
                self.metrics.queries.fetch_add(n as u64, Ordering::Relaxed);
                self.metrics.query_batches.fetch_add(1, Ordering::Relaxed);
                let span = Arc::new(ReadSpan::default());
                let opts = self
                    .query_opts()
                    .with_observer(self.metrics.stages.clone(), Some(Arc::clone(&span)));
                let qs: Vec<_> = vecs.iter().map(|v| self.sketcher.sketch(v)).collect();
                let results = router::topk_batch_with(&self.store, &qs, k, &opts);
                let total = start.elapsed().as_secs_f64();
                // per-query latency, so single and batched queries compare
                self.metrics.record_query_latency(total / n.max(1) as f64);
                self.note_slow_read("query_batch", trace, k, total, &span);
                Response::HitsBatch { results }
            }
            Request::Distance { a, b } => {
                self.metrics.distances.fetch_add(1, Ordering::Relaxed);
                match router::distance(&self.store, a, b) {
                    Some(dist) => Response::Distance { dist },
                    None => Response::Error {
                        message: "unknown id".into(),
                    },
                }
            }
            Request::Heatmap => {
                self.metrics.heatmaps.fetch_add(1, Ordering::Relaxed);
                // id-ordered arena snapshot: the all-pairs scan runs over
                // borrowed rows, no per-sketch BitVec in the hot loop. The
                // size guard runs on the snapshot itself (store.len()
                // counts allocated ids, including batches still in flight,
                // and checking before snapshotting would race inserts).
                let matrix = self.store.snapshot_matrix();
                if matrix.len() > self.config.heatmap_limit {
                    return Response::Error {
                        message: format!(
                            "corpus {} exceeds heatmap limit {}",
                            matrix.len(),
                            self.config.heatmap_limit
                        ),
                    };
                }
                let hm = crate::analysis::heatmap::Heatmap::from_matrix_occupancy(&matrix, 2.0);
                Response::Heatmap {
                    n: hm.n,
                    values: hm.values,
                }
            }
            Request::Promote => match &self.replica {
                Some(r) => match r.promote() {
                    Ok((applied_seqs, epoch)) => {
                        self.failover.last_epoch.store(epoch, Ordering::Relaxed);
                        obs_log::info(
                            "coordinator",
                            "promoted",
                            &[
                                ("epoch", obs_log::V::u(epoch)),
                                ("applied_seqs", obs_log::V::s(format!("{applied_seqs:?}"))),
                            ],
                        );
                        Response::Promoted { applied_seqs, epoch }
                    }
                    Err(e) => {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        Response::Error {
                            message: format!("{e:#}"),
                        }
                    }
                },
                None => {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    Response::Error {
                        message: "not a replica (this server was started without \
                                  --replicate-from)"
                            .into(),
                    }
                }
            },
            Request::Demote { epoch } => match self.store.persistence() {
                Some(p) => {
                    // fence at the highest term we know of: our own
                    // epoch, the operator-supplied one (usually the new
                    // primary's), and any existing fence — a demote can
                    // upgrade a fence, never downgrade it
                    let own = p.epoch();
                    let fence_at = epoch
                        .unwrap_or(own)
                        .max(own)
                        .max(self.fenced.load(Ordering::SeqCst));
                    if let Err(e) =
                        crate::persist::manifest::write_fence(p.data_dir(), fence_at)
                    {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        return Response::Error {
                            message: format!("persisting the fence marker: {e:#}"),
                        };
                    }
                    self.fenced.store(fence_at, Ordering::SeqCst);
                    self.failover.fence_events.fetch_add(1, Ordering::Relaxed);
                    self.failover.last_epoch.store(fence_at, Ordering::Relaxed);
                    obs_log::warn(
                        "coordinator",
                        "demoted",
                        &[
                            ("own_epoch", obs_log::V::u(own)),
                            ("fenced_at", obs_log::V::u(fence_at)),
                        ],
                    );
                    obs::journal::record(
                        "coordinator",
                        "demoted",
                        &[
                            ("own_epoch", obs_log::V::u(own)),
                            ("fenced_at", obs_log::V::u(fence_at)),
                        ],
                    );
                    Response::Demoted { epoch: fence_at }
                }
                None => {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    Response::Error {
                        message: "demote requires persistence (--data-dir): the fence \
                                  marker must survive a restart to be worth anything"
                            .into(),
                    }
                }
            },
            Request::Stats => Response::Stats {
                fields: self.stats_fields(),
            },
        }
    }

    /// The full flat stats field set: traffic counters, stage histogram
    /// summaries, the (read-only) index and persistence configuration,
    /// live persistence gauges, and the replica role. Backs both the
    /// `stats` response and the Prometheus `metrics_text` exposition.
    fn stats_fields(&self) -> Vec<(String, f64)> {
        let mut fields = self.metrics.snapshot();
        fields.extend(self.config.index.stats_fields());
        fields.extend(self.config.persist.stats_fields());
        if let Some(p) = self.store.persistence() {
            // live gauges that only the persistence handle knows:
            // the size-trigger/operator WAL gauge, and per-shard
            // durable seq horizons — the same field a follower
            // reports, so "caught up" is one comparison
            fields.push(("persist_wal_live_bytes".into(), p.wal_live_bytes() as f64));
            for si in 0..self.store.num_shards() {
                fields.push((
                    format!("persist_next_seq_shard{si}"),
                    p.committed_seq(si) as f64,
                ));
            }
        }
        let role = match &self.replica {
            None => 0.0,
            Some(r) if !r.is_writable() => 1.0,
            Some(_) => 2.0, // promoted
        };
        fields.push(("repl_role".into(), role));
        // the failover surface: the durable epoch (0 = non-durable, no
        // epoch), whether this server is fenced, and the probe/promotion
        // counters shared with the replica runtime's supervisor
        fields.push(("repl_epoch".into(), self.current_epoch().unwrap_or(0) as f64));
        fields.push((
            "failover_fenced".into(),
            self.fenced.load(Ordering::SeqCst) as f64,
        ));
        fields.extend(self.failover.stats_fields());
        // the operator's advisory staleness budget (0 = unset) and the
        // flight-recorder fill level
        fields.push((
            "cfg_max_read_staleness_ms".into(),
            self.config.max_read_staleness_ms as f64,
        ));
        fields.push(("journal_events".into(), obs::journal::events() as f64));
        fields.push(("journal_dropped".into(), obs::journal::dropped() as f64));
        fields
    }

    /// Emit one structured slow-op record for a read request that crossed
    /// `--slow-op-ms`, with the span's critical-path per-stage breakdown
    /// (max across the parallel shard jobs, the time that actually
    /// bounded the request).
    fn note_slow_read(&self, op: &str, trace: u64, k: usize, total_s: f64, span: &ReadSpan) {
        let threshold = obs::slow_op_us();
        if threshold == 0 || total_s * 1e6 < threshold as f64 {
            return;
        }
        obs_log::warn(
            "server",
            "slow_op",
            &[
                ("op", obs_log::V::s(op)),
                ("trace", obs_log::V::u(trace)),
                ("k", obs_log::V::u(k as u64)),
                ("total_ms", obs_log::V::f(total_s * 1e3)),
                ("queue_ms", obs_log::V::f(span.ms(&span.queue_us))),
                ("scan_ms", obs_log::V::f(span.ms(&span.scan_us))),
                ("rerank_ms", obs_log::V::f(span.ms(&span.rerank_us))),
                ("gather_ms", obs_log::V::f(span.ms(&span.gather_us))),
            ],
        );
        obs::journal::record(
            "server",
            "slow_op",
            &[
                ("op", obs_log::V::s(op)),
                ("trace", obs_log::V::u(trace)),
                ("total_ms", obs_log::V::f(total_s * 1e3)),
            ],
        );
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Serve on `addr` ("127.0.0.1:0" for an ephemeral port). Returns the
    /// bound address through `on_bound` and blocks until a Shutdown
    /// request arrives.
    pub fn serve<F: FnOnce(std::net::SocketAddr)>(self: &Arc<Self>, addr: &str, on_bound: F) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        // TTL sweep: a primary-side background task that turns passed
        // deadlines into ordinary (replicated, durable) deletions. An
        // unpromoted replica skips the tick — it mirrors the primary's
        // sweep from the shipped log instead — but keeps polling, so a
        // later promotion picks the sweep duty up automatically.
        let sweeper = (self.config.ttl_sweep_ms > 0).then(|| {
            let me = Arc::clone(self);
            std::thread::spawn(move || {
                let period = Duration::from_millis(me.config.ttl_sweep_ms);
                let nap = period.min(Duration::from_millis(50));
                let mut slept = Duration::ZERO;
                while !me.is_shutdown() {
                    // chunked sleep so shutdown never waits a full period
                    std::thread::sleep(nap);
                    slept += nap;
                    if slept < period {
                        continue;
                    }
                    slept = Duration::ZERO;
                    if me.replica.as_ref().is_some_and(|r| !r.is_writable()) {
                        continue;
                    }
                    // failpoint: `ttl_sweep` armed = the tick is skipped
                    // (Err) or stalled (sleep), freezing expiry reaping
                    // without touching any clock
                    if crate::fault::check("ttl_sweep").is_err() {
                        continue;
                    }
                    let swept = me.store.sweep_expired(now_ms());
                    if swept > 0 {
                        me.metrics
                            .ttl_expirations
                            .fetch_add(swept as u64, Ordering::Relaxed);
                    }
                }
            })
        });
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.is_shutdown() {
            match listener.accept() {
                Ok((stream, _)) => {
                    // failpoint: `accept` armed = simulated network
                    // partition — the connection is dropped on the floor
                    // (the peer sees an immediate EOF), the serve loop
                    // stays healthy
                    if crate::fault::check("accept").is_err() {
                        drop(stream);
                        continue;
                    }
                    let me = Arc::clone(self);
                    conns.push(std::thread::spawn(move || {
                        let _ = me.handle_connection(stream);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    obs_log::error(
                        "coordinator",
                        "accept_error",
                        &[("error", obs_log::V::s(format!("{e}")))],
                    );
                    break;
                }
            }
        }
        for c in conns {
            let _ = c.join();
        }
        if let Some(s) = sweeper {
            let _ = s.join();
        }
        // belt-and-braces: the Shutdown request already flushed, but late
        // connection work may have appended since
        if self.store.persistence().is_some() {
            if let Err(e) = self.store.persist_flush() {
                obs_log::error(
                    "coordinator",
                    "final_flush_failed",
                    &[("error", obs_log::V::s(format!("{e:#}")))],
                );
            }
        }
        Ok(())
    }

    fn handle_connection(&self, stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let mut line = String::new();
        // trace id: connection number in the millions digit, request
        // sequence below — unique per request, cheap to correlate by eye
        let conn = self.next_conn.fetch_add(1, Ordering::Relaxed) + 1;
        let mut req_seq: u64 = 0;
        loop {
            line.clear();
            let n = reader.read_line(&mut line)?;
            if n == 0 {
                return Ok(()); // client hung up
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            // failpoint: `conn_read` armed = the connection dies after a
            // request is read but before it is dispatched (a torn
            // request from the client's point of view — it sees EOF with
            // no reply and cannot know whether the write applied)
            if crate::fault::check("conn_read").is_err() {
                return Ok(());
            }
            // Stream ops (repl_snapshot / repl_wal_tail / metrics_text):
            // replies are a JSON header line + raw payload bytes, which
            // the Response enum cannot carry — parse the StreamRequest
            // envelope (the canonical `"stream"` key) before request
            // parsing and route through the single dispatch point below.
            if StreamRequest::looks_like(trimmed) {
                match StreamRequest::from_json_line(trimmed) {
                    Ok(Some(sreq)) => {
                        self.handle_stream(&sreq, &mut writer)?;
                        continue;
                    }
                    Ok(None) => {} // ordinary request; fall through
                    Err(e) => {
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let resp = Response::Error {
                            message: format!("{e:#}"),
                        };
                        writeln!(writer, "{}", resp.to_json_line())?;
                        continue;
                    }
                }
            }
            req_seq += 1;
            let stamped = conn.saturating_mul(1_000_000).saturating_add(req_seq);
            let resp = match Request::parse_with_trace(trimmed, self.config.input_dim) {
                Ok((req, wire_trace)) => {
                    // a wire-supplied trace id wins over the stamped one:
                    // that is what lets one id follow a request across
                    // nodes (the MultiClient re-sends its trace on every
                    // redirect/retry hop)
                    let trace = wire_trace.unwrap_or(stamped);
                    if wire_trace.is_some() {
                        obs_log::info(
                            "server",
                            "traced_op",
                            &[
                                ("op", obs_log::V::s(req.op_name())),
                                ("trace", obs_log::V::u(trace)),
                            ],
                        );
                    }
                    let is_shutdown = matches!(req, Request::Shutdown);
                    let r = self.handle_request_traced(req, trace);
                    if is_shutdown {
                        writeln!(writer, "{}", r.to_json_line())?;
                        return Ok(());
                    }
                    r
                }
                Err(e) => {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    Response::Error {
                        message: format!("{e:#}"),
                    }
                }
            };
            // failpoint: `conn_write` armed = the connection dies after
            // dispatch but before the reply lands (the op applied
            // server-side; the client must treat the lost ack as
            // ambiguous and re-resolve)
            if crate::fault::check("conn_write").is_err() {
                return Ok(());
            }
            writeln!(writer, "{}", resp.to_json_line())?;
        }
    }

    /// The one routing point for parsed stream ops (header line + raw
    /// payload framing — see [`StreamRequest`] and `docs/PROTOCOL.md`).
    /// Replication ops are served by any durable node (a follower can
    /// feed further followers; a non-durable server answers an error
    /// line); `metrics_text` is served by primaries and followers alike —
    /// scraping must not depend on role. Transport failures bubble as
    /// `io::Error` like any connection write.
    fn handle_stream<W: Write>(&self, req: &StreamRequest, writer: &mut W) -> std::io::Result<()> {
        match req {
            StreamRequest::ReplSnapshot { trace } => replica::shipper::serve_snapshot(
                &self.store,
                &self.metrics.repl,
                trace.unwrap_or(0),
                writer,
            ),
            StreamRequest::ReplWalTail {
                shard,
                from_seq,
                max_bytes,
                epoch,
                trace,
            } => {
                // Fence check before the shipper (which stays
                // fence-unaware): a follower whose epoch is higher than
                // ours was promoted over us — shipping it frames as if we
                // were still its primary would be exactly the split-brain
                // the epoch exists to prevent.
                if let Some(peer) = epoch {
                    if let Some(resp) = self.observe_epoch(*peer) {
                        writeln!(writer, "{}", resp.to_json_line())?;
                        return Ok(());
                    }
                }
                replica::shipper::serve_wal_tail(
                    &self.store,
                    &self.metrics.repl,
                    *shard,
                    *from_seq,
                    *max_bytes,
                    trace.unwrap_or(0),
                    writer,
                )
            }
            StreamRequest::MetricsText => self.serve_metrics_text(writer),
            StreamRequest::Events => self.serve_events(writer),
        }
    }

    /// Serve `metrics_text`: Prometheus text exposition of every stats
    /// field plus full histogram bucket families. Replies with a
    /// `{"ok":true,"bytes":N}` header line followed by N raw payload
    /// bytes, mirroring the replication stream ops' framing (the text
    /// body cannot ride the line-JSON `Response` enum).
    fn serve_metrics_text<W: Write>(&self, writer: &mut W) -> std::io::Result<()> {
        let body = obs::prom::render(&self.stats_fields(), &self.metrics.histogram_snapshots());
        let header = crate::util::json::Json::obj(vec![
            ("ok", crate::util::json::Json::Bool(true)),
            ("bytes", crate::util::json::Json::Num(body.len() as f64)),
        ]);
        writeln!(writer, "{header}")?;
        writer.write_all(body.as_bytes())?;
        writer.flush()?;
        Ok(())
    }

    /// Serve `events`: the flight-recorder journal as JSONL, framed like
    /// `metrics_text` (`{"ok":true,"bytes":N}` header + N payload bytes).
    /// The journal is process-global, so any node answers with its own
    /// local timeline.
    fn serve_events<W: Write>(&self, writer: &mut W) -> std::io::Result<()> {
        let body = obs::journal::render_jsonl();
        let header = crate::util::json::Json::obj(vec![
            ("ok", crate::util::json::Json::Bool(true)),
            ("bytes", crate::util::json::Json::Num(body.len() as f64)),
        ]);
        writeln!(writer, "{header}")?;
        writer.write_all(body.as_bytes())?;
        writer.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CatVector;
    use crate::util::rng::Xoshiro256;

    fn test_config() -> CoordinatorConfig {
        CoordinatorConfig {
            input_dim: 600,
            num_categories: 10,
            sketch_dim: 128,
            seed: 5,
            num_shards: 2,
            use_xla: false,
            ..Default::default()
        }
    }

    #[test]
    fn insert_then_query_roundtrip() {
        let c = Coordinator::new(test_config());
        let mut rng = Xoshiro256::new(1);
        let vecs: Vec<CatVector> = (0..12)
            .map(|_| CatVector::random(600, 40, 10, &mut rng))
            .collect();
        for v in &vecs {
            match c.handle_request(Request::Insert { vec: v.clone() }) {
                Response::Inserted { .. } => {}
                other => panic!("{other:?}"),
            }
        }
        // query with an inserted vector: itself must be the top hit
        match c.handle_request(Request::Query {
            vec: vecs[3].clone(),
            k: 3,
        }) {
            Response::Hits { hits } => {
                assert_eq!(hits.len(), 3);
                assert!(hits[0].dist < 1e-9, "{hits:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batched_query_matches_single_queries() {
        let c = Coordinator::new(test_config());
        let mut rng = Xoshiro256::new(7);
        let vecs: Vec<CatVector> = (0..10)
            .map(|_| CatVector::random(600, 40, 10, &mut rng))
            .collect();
        for v in &vecs {
            c.handle_request(Request::Insert { vec: v.clone() });
        }
        let probes: Vec<CatVector> = vecs[..4].to_vec();
        let batched = match c.handle_request(Request::QueryBatch {
            vecs: probes.clone(),
            k: 3,
        }) {
            Response::HitsBatch { results } => results,
            other => panic!("{other:?}"),
        };
        assert_eq!(batched.len(), 4);
        for (probe, hits) in probes.iter().zip(&batched) {
            match c.handle_request(Request::Query {
                vec: probe.clone(),
                k: 3,
            }) {
                Response::Hits { hits: single } => assert_eq!(&single, hits),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn delete_upsert_and_ttl_serve_through_the_request_path() {
        let c = Coordinator::new(test_config());
        let mut rng = Xoshiro256::new(51);
        let vecs: Vec<CatVector> = (0..6)
            .map(|_| CatVector::random(600, 40, 10, &mut rng))
            .collect();
        let mut ids = Vec::new();
        for v in &vecs {
            match c.handle_request(Request::Insert { vec: v.clone() }) {
                Response::Inserted { id, .. } => ids.push(id),
                other => panic!("{other:?}"),
            }
        }
        // delete: the id must stop appearing in query results
        match c.handle_request(Request::Delete { id: ids[2] }) {
            Response::Deleted { id, .. } => assert_eq!(id, ids[2]),
            other => panic!("{other:?}"),
        }
        match c.handle_request(Request::Query {
            vec: vecs[2].clone(),
            k: 5,
        }) {
            Response::Hits { hits } => {
                assert!(hits.iter().all(|h| h.id != ids[2]), "{hits:?}")
            }
            other => panic!("{other:?}"),
        }
        // deleting an unheld id is a client error, not a crash
        match c.handle_request(Request::Delete { id: ids[2] }) {
            Response::Error { message } => assert!(message.contains("does not hold"), "{message}"),
            other => panic!("{other:?}"),
        }
        // upsert: the id now answers for the replacement vector
        match c.handle_request(Request::Upsert {
            id: ids[4],
            vec: vecs[0].clone(),
            ttl_ms: 0,
        }) {
            Response::Upserted { id, .. } => assert_eq!(id, ids[4]),
            other => panic!("{other:?}"),
        }
        match c.handle_request(Request::Query {
            vec: vecs[0].clone(),
            k: 2,
        }) {
            Response::Hits { hits } => {
                assert!(hits.iter().take(2).any(|h| h.id == ids[4]), "{hits:?}");
                assert!(hits[0].dist < 1e-9);
            }
            other => panic!("{other:?}"),
        }
        // TTL insert: expired rows fall to the sweep (driven directly
        // here; `serve` runs it on a timer)
        match c.handle_request(Request::InsertTtl {
            vec: vecs[1].clone(),
            ttl_ms: 1,
        }) {
            Response::Inserted { .. } => {}
            other => panic!("{other:?}"),
        }
        let live_before = c.store.live_len();
        // deadline = now + 1ms; sweeping "one hour later" must reap it
        let swept = c.store.sweep_expired(now_ms() + 3_600_000);
        assert_eq!(swept, 1);
        assert_eq!(c.store.live_len(), live_before - 1);
        match c.handle_request(Request::Stats) {
            Response::Stats { fields } => {
                let get = |k: &str| {
                    super::super::metrics::stats_field(&fields, k)
                        .unwrap_or_else(|| panic!("stats field '{k}' missing"))
                };
                assert_eq!(get("deletes"), 1.0);
                assert_eq!(get("upserts"), 1.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn query_k_zero_in_process_returns_empty() {
        // The protocol layer rejects k == 0 on the wire; a programmatic
        // request must degrade to "no hits", never a panic.
        let c = Coordinator::new(test_config());
        let mut rng = Xoshiro256::new(8);
        let v = CatVector::random(600, 40, 10, &mut rng);
        c.handle_request(Request::Insert { vec: v.clone() });
        match c.handle_request(Request::Query { vec: v, k: 0 }) {
            Response::Hits { hits } => assert!(hits.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn distance_and_stats() {
        let c = Coordinator::new(test_config());
        let mut rng = Xoshiro256::new(2);
        let a = CatVector::random(600, 40, 10, &mut rng);
        let b = CatVector::random(600, 40, 10, &mut rng);
        let ida = match c.handle_request(Request::Insert { vec: a.clone() }) {
            Response::Inserted { id, .. } => id,
            _ => panic!(),
        };
        let idb = match c.handle_request(Request::Insert { vec: b.clone() }) {
            Response::Inserted { id, .. } => id,
            _ => panic!(),
        };
        let truth = a.hamming(&b) as f64;
        match c.handle_request(Request::Distance { a: ida, b: idb }) {
            Response::Distance { dist } => {
                assert!((dist - truth).abs() < 0.5 * truth + 30.0, "{dist} vs {truth}");
            }
            other => panic!("{other:?}"),
        }
        match c.handle_request(Request::Stats) {
            Response::Stats { fields } => {
                // total lookup (None on absence), not find(..).unwrap()
                let get = |k: &str| {
                    super::super::metrics::stats_field(&fields, k)
                        .unwrap_or_else(|| panic!("stats field '{k}' missing: {fields:?}"))
                };
                assert_eq!(get("inserts"), 2.0);
                assert_eq!(get("distances"), 1.0);
                // the index configuration rides along in every Stats reply
                assert_eq!(get("index_cfg_bands"), 8.0);
                assert!(get("index_cfg_mode") >= 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn index_on_serves_queries_and_counts_traffic() {
        use crate::index::{IndexConfig, IndexMode};
        let cfg = CoordinatorConfig {
            index: IndexConfig {
                mode: IndexMode::On,
                ..Default::default()
            },
            ..test_config()
        };
        let c = Coordinator::new(cfg);
        let mut rng = Xoshiro256::new(9);
        let vecs: Vec<CatVector> = (0..20)
            .map(|_| CatVector::random(600, 40, 10, &mut rng))
            .collect();
        let mut ids = Vec::new();
        for v in &vecs {
            match c.handle_request(Request::Insert { vec: v.clone() }) {
                Response::Inserted { id, .. } => ids.push(id),
                other => panic!("{other:?}"),
            }
        }
        // an inserted vector sketches identically → collides in every band
        // → it is always its own top hit, indexed or fallen back
        for (i, v) in vecs.iter().enumerate().take(5) {
            match c.handle_request(Request::Query {
                vec: v.clone(),
                k: 3,
            }) {
                Response::Hits { hits } => {
                    assert_eq!(hits.len(), 3);
                    assert_eq!(hits[0].id, ids[i], "{hits:?}");
                    assert!(hits[0].dist < 1e-9, "{hits:?}");
                }
                other => panic!("{other:?}"),
            }
        }
        // every shard scan went through the index path (mode = On)
        let m = &c.metrics.index;
        use std::sync::atomic::Ordering::Relaxed;
        assert!(m.probes.load(Relaxed) > 0);
        assert_eq!(
            m.indexed_scans.load(Relaxed) + m.fallbacks.load(Relaxed),
            5 * c.store.num_shards() as u64
        );
    }

    #[test]
    fn metrics_text_routes_pre_parse_and_frames_header_plus_payload() {
        let c = Coordinator::new(test_config());
        let mut rng = Xoshiro256::new(13);
        for _ in 0..4 {
            c.handle_request(Request::Insert {
                vec: CatVector::random(600, 40, 10, &mut rng),
            });
        }
        c.handle_request(Request::Query {
            vec: CatVector::random(600, 40, 10, &mut rng),
            k: 2,
        });
        // non-matching lines fall through to the ordinary request path —
        // including the removed deprecated `"op"` spelling, which then
        // draws an unknown-op error from Request parsing
        assert_eq!(
            StreamRequest::from_json_line(r#"{"op":"ping"}"#).unwrap(),
            None
        );
        assert_eq!(
            StreamRequest::from_json_line(r#"{"op":"metrics_text"}"#).unwrap(),
            None
        );
        // a canonical metrics_text envelope answers header + exactly
        // `bytes` of payload
        let sreq = StreamRequest::from_json_line(r#"{"stream":"metrics_text"}"#)
            .unwrap()
            .expect("canonical envelope parses");
        let mut out = Vec::new();
        c.handle_stream(&sreq, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let (header, body) = text.split_once('\n').unwrap();
        let h = crate::util::json::parse(header).unwrap();
        assert_eq!(h.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(h.req_usize("bytes").unwrap(), body.len());
        // the exposition carries counters, stage histograms, and gauges
        assert!(body.contains("# TYPE cabin_inserts_total counter"), "{body}");
        assert!(body.contains("cabin_stage_read_scan_seconds_bucket"), "{body}");
        assert!(body.contains("cabin_query_latency_seconds_count"), "{body}");
        assert!(body.contains("le=\"+Inf\""), "{body}");
    }

    #[test]
    fn heatmap_limit_enforced() {
        let mut cfg = test_config();
        cfg.heatmap_limit = 2;
        let c = Coordinator::new(cfg);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..3 {
            c.handle_request(Request::Insert {
                vec: CatVector::random(600, 20, 10, &mut rng),
            });
        }
        match c.handle_request(Request::Heatmap) {
            Response::Error { message } => assert!(message.contains("limit")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn shutdown_flag() {
        let c = Coordinator::new(test_config());
        assert!(!c.is_shutdown());
        assert_eq!(c.handle_request(Request::Shutdown), Response::ShuttingDown);
        assert!(c.is_shutdown());
    }

    #[test]
    fn persist_mode_without_data_dir_is_a_config_error_not_a_silent_fallback() {
        use crate::persist::{PersistConfig, PersistMode};
        let cfg = CoordinatorConfig {
            persist: PersistConfig {
                mode: PersistMode::Wal,
                data_dir: None,
                ..Default::default()
            },
            ..test_config()
        };
        let err = Coordinator::try_new(cfg).unwrap_err().to_string();
        assert!(err.contains("data_dir"), "{err}");
    }

    #[test]
    fn flush_and_snapshot_require_persistence() {
        let c = Coordinator::new(test_config());
        for req in [Request::Flush, Request::Snapshot] {
            match c.handle_request(req) {
                Response::Error { message } => {
                    assert!(message.contains("persistence"), "{message}")
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn promote_requires_a_replica() {
        let c = Coordinator::new(test_config());
        match c.handle_request(Request::Promote) {
            Response::Error { message } => {
                assert!(message.contains("--replicate-from"), "{message}")
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn replicate_from_requires_a_data_dir() {
        let cfg = CoordinatorConfig {
            replicate_from: Some("127.0.0.1:1".into()),
            ..test_config()
        };
        let err = Coordinator::try_new(cfg).unwrap_err().to_string();
        assert!(err.contains("--data-dir"), "{err}");
    }

    #[test]
    fn stats_report_wal_live_bytes_and_next_seqs() {
        use crate::persist::{FsyncPolicy, PersistConfig, PersistMode};
        use crate::testing::TempDir;
        let dir = TempDir::new("server-seq-stats");
        let cfg = CoordinatorConfig {
            persist: PersistConfig {
                mode: PersistMode::Wal,
                data_dir: Some(dir.path().to_path_buf()),
                fsync: FsyncPolicy::Never,
                ..PersistConfig::default()
            },
            ..test_config()
        };
        let c = Coordinator::try_new(cfg).unwrap();
        let mut rng = Xoshiro256::new(44);
        for _ in 0..3 {
            match c.handle_request(Request::Insert {
                vec: CatVector::random(600, 40, 10, &mut rng),
            }) {
                Response::Inserted { .. } => {}
                other => panic!("{other:?}"),
            }
        }
        match c.handle_request(Request::Stats) {
            Response::Stats { fields } => {
                let get = |k: &str| {
                    super::super::metrics::stats_field(&fields, k)
                        .unwrap_or_else(|| panic!("stats field '{k}' missing"))
                };
                assert!(get("persist_wal_live_bytes") > 0.0);
                // 2 shards: both per-shard seq horizons present, summing
                // to the 3 inserted frames
                let total = get("persist_next_seq_shard0") + get("persist_next_seq_shard1");
                assert_eq!(total, 3.0);
                assert_eq!(get("repl_role"), 0.0);
                assert_eq!(get("repl_frames_shipped"), 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn executor_serves_queries_and_reports_stats() {
        // no serving path spawns threads per request: the scatter totals
        // must line up exactly with the executor's job counters
        let c = Coordinator::new(test_config());
        let mut rng = Xoshiro256::new(21);
        for _ in 0..6 {
            c.handle_request(Request::Insert {
                vec: CatVector::random(600, 40, 10, &mut rng),
            });
        }
        for _ in 0..3 {
            c.handle_request(Request::Query {
                vec: CatVector::random(600, 40, 10, &mut rng),
                k: 2,
            });
        }
        c.handle_request(Request::QueryBatch {
            vecs: (0..4)
                .map(|_| CatVector::random(600, 40, 10, &mut rng))
                .collect(),
            k: 2,
        });
        match c.handle_request(Request::Stats) {
            Response::Stats { fields } => {
                let get = |k: &str| {
                    super::super::metrics::stats_field(&fields, k)
                        .unwrap_or_else(|| panic!("stats field '{k}' missing"))
                };
                // 3 single queries + 1 batch = 4 scatters, each one job
                // per shard (2 shards in test_config)
                assert_eq!(get("executor_scatters"), 4.0);
                assert_eq!(get("executor_jobs"), 8.0);
                assert_eq!(get("executor_queue_depth"), 0.0);
                assert_eq!(get("executor_busy_workers"), 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wal_commit_failure_is_a_client_visible_insert_error() {
        use crate::persist::{FsyncPolicy, PersistConfig, PersistMode};
        use crate::testing::TempDir;
        let dir = TempDir::new("server-commit-fail");
        let cfg = CoordinatorConfig {
            persist: PersistConfig {
                mode: PersistMode::Wal,
                data_dir: Some(dir.path().to_path_buf()),
                fsync: FsyncPolicy::Never,
                ..PersistConfig::default()
            },
            ..test_config()
        };
        let c = Coordinator::try_new(cfg).unwrap();
        let mut rng = Xoshiro256::new(33);
        // a clean insert acks normally
        match c.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        }) {
            Response::Inserted { .. } => {}
            other => panic!("{other:?}"),
        }
        // inject a commit failure on every shard (placement is
        // least-loaded, so the next insert may land anywhere)
        let p = c.store.persistence().unwrap();
        for si in 0..c.store.num_shards() {
            p.wal_guard(si).fail_next_commit("injected disk failure");
        }
        match c.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        }) {
            Response::Error { message } => {
                assert!(message.contains("not acknowledged as durable"), "{message}");
            }
            other => panic!("durability failure must not ack: {other:?}"),
        }
        assert_eq!(c.metrics.errors.load(Ordering::Relaxed), 1);
        // consume the injection still armed on the shard the failing
        // insert did NOT land on (placement is least-loaded, so the next
        // insert would otherwise trip it and this test would flake on
        // placement order)
        for si in 0..c.store.num_shards() {
            let _ = p.wal_guard(si).commit();
        }
        // the writer retries its pending frames: service recovers
        match c.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        }) {
            Response::Inserted { .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn durable_coordinator_recovers_its_corpus() {
        use crate::persist::{FsyncPolicy, PersistConfig, PersistMode};
        use crate::testing::TempDir;
        let dir = TempDir::new("server-durable");
        let cfg = || CoordinatorConfig {
            persist: PersistConfig {
                mode: PersistMode::WalSnapshot,
                data_dir: Some(dir.path().to_path_buf()),
                fsync: FsyncPolicy::Never,
                snapshot_every: 0, // manual snapshots only
                ..PersistConfig::default()
            },
            ..test_config()
        };
        let mut rng = Xoshiro256::new(17);
        let vecs: Vec<CatVector> = (0..10)
            .map(|_| CatVector::random(600, 40, 10, &mut rng))
            .collect();
        let (ids, pre_hits) = {
            let c = Coordinator::try_new(cfg()).unwrap();
            let mut ids = Vec::new();
            for v in &vecs {
                match c.handle_request(Request::Insert { vec: v.clone() }) {
                    Response::Inserted { id, .. } => ids.push(id),
                    other => panic!("{other:?}"),
                }
            }
            // half the corpus is snapshotted, half stays WAL-tail-only
            match c.handle_request(Request::Snapshot) {
                Response::Snapshotted { generation } => assert_eq!(generation, 1),
                other => panic!("{other:?}"),
            }
            for v in &vecs[5..] {
                c.handle_request(Request::Insert { vec: v.clone() });
            }
            assert_eq!(c.handle_request(Request::Flush), Response::Flushed);
            let hits = match c.handle_request(Request::Query {
                vec: vecs[3].clone(),
                k: 5,
            }) {
                Response::Hits { hits } => hits,
                other => panic!("{other:?}"),
            };
            (ids, hits)
        };
        // second coordinator over the same data dir: the corpus is back
        let c = Coordinator::try_new(cfg()).unwrap();
        assert_eq!(c.store.len(), 15);
        match c.handle_request(Request::Query {
            vec: vecs[3].clone(),
            k: 5,
        }) {
            Response::Hits { hits } => {
                assert_eq!(hits, pre_hits, "recovered top-k must match pre-crash");
                assert_eq!(hits[0].id, ids[3]);
                assert!(hits[0].dist < 1e-9);
            }
            other => panic!("{other:?}"),
        }
        // persist_* stats surface the recovery
        match c.handle_request(Request::Stats) {
            Response::Stats { fields } => {
                let get = |k: &str| super::super::metrics::stats_field(&fields, k).unwrap();
                assert_eq!(get("persist_generation"), 1.0);
                assert_eq!(get("persist_cfg_mode"), 2.0);
            }
            other => panic!("{other:?}"),
        }
    }

    fn durable_config(dir: &std::path::Path) -> CoordinatorConfig {
        use crate::persist::{FsyncPolicy, PersistConfig, PersistMode};
        CoordinatorConfig {
            persist: PersistConfig {
                mode: PersistMode::Wal,
                data_dir: Some(dir.to_path_buf()),
                fsync: FsyncPolicy::Never,
                ..PersistConfig::default()
            },
            ..test_config()
        }
    }

    #[test]
    fn durable_acks_and_pong_carry_the_epoch() {
        use crate::testing::TempDir;
        let dir = TempDir::new("server-epoch-acks");
        let c = Coordinator::try_new(durable_config(dir.path())).unwrap();
        let mut rng = Xoshiro256::new(61);
        match c.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        }) {
            Response::Inserted { epoch, .. } => assert_eq!(epoch, Some(1)),
            other => panic!("{other:?}"),
        }
        assert_eq!(
            c.handle_request(Request::Ping { epoch: None }),
            Response::Pong { epoch: Some(1) }
        );
        // a non-durable server has no epoch: its replies omit the field
        // (wire bytes unchanged from the pre-epoch protocol)
        let plain = Coordinator::new(test_config());
        assert_eq!(
            plain.handle_request(Request::Ping { epoch: None }),
            Response::Pong { epoch: None }
        );
        match plain.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        }) {
            Response::Inserted { epoch, .. } => assert_eq!(epoch, None),
            other => panic!("{other:?}"),
        }
        match plain.handle_request(Request::Stats) {
            Response::Stats { fields } => {
                let get = |k: &str| super::super::metrics::stats_field(&fields, k).unwrap();
                assert_eq!(get("repl_epoch"), 0.0);
                assert_eq!(get("failover_fenced"), 0.0);
                assert_eq!(get("failover_probes"), 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn higher_peer_epoch_fences_a_durable_primary_across_restarts() {
        use crate::testing::TempDir;
        let dir = TempDir::new("server-fence");
        let mut rng = Xoshiro256::new(62);
        {
            let c = Coordinator::try_new(durable_config(dir.path())).unwrap();
            // the ping itself still answers pong (probe semantics), but
            // the higher peer epoch riding on it fences the server
            assert_eq!(
                c.handle_request(Request::Ping { epoch: Some(9) }),
                Response::Pong { epoch: Some(1) }
            );
            match c.handle_request(Request::Insert {
                vec: CatVector::random(600, 40, 10, &mut rng),
            }) {
                Response::Error { message } => {
                    assert!(message.contains("fenced"), "{message}");
                    assert!(message.contains("epoch 9"), "{message}");
                    assert!(message.contains("own epoch 1"), "{message}");
                }
                other => panic!("fenced server must not ack writes: {other:?}"),
            }
            match c.handle_request(Request::Stats) {
                Response::Stats { fields } => {
                    let get =
                        |k: &str| super::super::metrics::stats_field(&fields, k).unwrap();
                    assert_eq!(get("failover_fenced"), 9.0);
                    assert_eq!(get("failover_fence_events"), 1.0);
                    assert_eq!(get("failover_last_epoch"), 9.0);
                    assert_eq!(get("repl_epoch"), 1.0);
                }
                other => panic!("{other:?}"),
            }
            // reads still serve — fencing is a write fence, not death
            match c.handle_request(Request::Stats) {
                Response::Stats { .. } => {}
                other => panic!("{other:?}"),
            }
        }
        // the marker survives: a restarted ex-primary comes back fenced
        assert_eq!(
            crate::persist::manifest::read_fence(dir.path()).unwrap(),
            Some(9)
        );
        let c = Coordinator::try_new(durable_config(dir.path())).unwrap();
        match c.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        }) {
            Response::Error { message } => assert!(message.contains("fenced"), "{message}"),
            other => panic!("fence must survive restart: {other:?}"),
        }
    }

    #[test]
    fn demote_fences_durable_servers_and_rejects_non_durable() {
        use crate::testing::TempDir;
        let c = Coordinator::new(test_config());
        match c.handle_request(Request::Demote { epoch: None }) {
            Response::Error { message } => {
                assert!(message.contains("persistence"), "{message}")
            }
            other => panic!("{other:?}"),
        }
        let dir = TempDir::new("server-demote");
        let c = Coordinator::try_new(durable_config(dir.path())).unwrap();
        // demote with no epoch fences at the server's own term
        assert_eq!(
            c.handle_request(Request::Demote { epoch: None }),
            Response::Demoted { epoch: 1 }
        );
        // re-demoting at the new primary's (higher) epoch upgrades the
        // fence; a lower one cannot downgrade it below our own term
        assert_eq!(
            c.handle_request(Request::Demote { epoch: Some(7) }),
            Response::Demoted { epoch: 7 }
        );
        assert_eq!(
            crate::persist::manifest::read_fence(dir.path()).unwrap(),
            Some(7)
        );
        let mut rng = Xoshiro256::new(63);
        match c.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        }) {
            Response::Error { message } => assert!(message.contains("fenced"), "{message}"),
            other => panic!("demoted server must not ack writes: {other:?}"),
        }
    }

    #[test]
    fn fenced_server_refuses_wal_tail_to_newer_follower() {
        use crate::testing::TempDir;
        let dir = TempDir::new("server-fence-tail");
        let c = Coordinator::try_new(durable_config(dir.path())).unwrap();
        let mut rng = Xoshiro256::new(64);
        c.handle_request(Request::Insert {
            vec: CatVector::random(600, 40, 10, &mut rng),
        });
        // a follower at our own epoch is served frames
        let tail = |epoch| StreamRequest::ReplWalTail {
            shard: 0,
            from_seq: 0,
            max_bytes: 1 << 20,
            epoch,
            trace: None,
        };
        let mut out = Vec::new();
        c.handle_stream(&tail(Some(1)), &mut out).unwrap();
        let header = String::from_utf8_lossy(&out);
        let header = header.split('\n').next().unwrap();
        let h = crate::util::json::parse(header).unwrap();
        assert_eq!(h.get("ok").and_then(|v| v.as_bool()), Some(true));
        // a follower reporting a higher epoch was promoted over us: the
        // tail request draws the fence error, not frames
        let mut out = Vec::new();
        c.handle_stream(&tail(Some(4)), &mut out).unwrap();
        let reply = String::from_utf8(out).unwrap();
        let resp = Response::from_json_line(reply.trim()).unwrap();
        match resp {
            Response::Error { message } => {
                assert!(message.contains("fenced"), "{message}");
                assert!(message.contains("epoch 4"), "{message}");
            }
            other => panic!("{other:?}"),
        }
    }
}
