//! Bounded top-k selection over a stream of (id, distance) candidates.
//!
//! A binary max-heap of capacity `k` keyed by `(dist, id)` under
//! [`f64::total_cmp`]: the root is the *worst* retained hit, so each
//! candidate costs one comparison against the root and — only when it
//! beats it — an O(log k) sift. This replaces the seed's
//! sort-on-every-insert buffer (O(k log k) per accepted candidate) and
//! performs zero allocations per candidate: the heap's backing storage is
//! reserved up front.
//!
//! `total_cmp` makes the kernel NaN-safe: a NaN distance is ordered after
//! every finite value, so it can never displace a real hit, never wins a
//! tie, and never panics a shard worker the way
//! `partial_cmp(..).unwrap()` did.

use super::protocol::Hit;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry ordered by `(dist, id)` ascending-lexicographic; the
/// `BinaryHeap` max-orientation then keeps the worst candidate at the root.
#[derive(Clone, Copy, Debug)]
struct Entry {
    dist: f64,
    id: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.dist
            .total_cmp(&other.dist)
            .then_with(|| self.id.cmp(&other.id))
    }
}

/// Bounded top-k accumulator (smallest `k` by `(dist, id)`).
pub struct TopK {
    k: usize,
    heap: BinaryHeap<Entry>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            heap: BinaryHeap::with_capacity(k),
        }
    }

    /// Offer one candidate. `k == 0` accepts nothing (and never panics).
    #[inline]
    pub fn offer(&mut self, id: usize, dist: f64) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(Entry { dist, id });
        } else if let Some(mut worst) = self.heap.peek_mut() {
            let candidate = Entry { dist, id };
            if candidate < *worst {
                *worst = candidate; // sifts down when the guard drops
            }
        }
    }

    /// Current number of retained hits.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drain into hits sorted ascending by `(dist, id)`.
    pub fn into_sorted_hits(self) -> Vec<Hit> {
        self.heap
            .into_sorted_vec()
            .into_iter()
            .map(|e| Hit {
                id: e.id,
                dist: e.dist,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_the_k_smallest_sorted() {
        let mut t = TopK::new(3);
        for (id, d) in [(0, 9.0), (1, 2.0), (2, 7.0), (3, 1.0), (4, 8.0), (5, 3.0)] {
            t.offer(id, d);
        }
        let hits = t.into_sorted_hits();
        let got: Vec<(usize, f64)> = hits.iter().map(|h| (h.id, h.dist)).collect();
        assert_eq!(got, vec![(3, 1.0), (1, 2.0), (5, 3.0)]);
    }

    #[test]
    fn k_zero_accepts_nothing() {
        let mut t = TopK::new(0);
        t.offer(1, 0.5);
        t.offer(2, f64::NAN);
        assert!(t.is_empty());
        assert!(t.into_sorted_hits().is_empty());
    }

    #[test]
    fn fewer_candidates_than_k() {
        let mut t = TopK::new(10);
        t.offer(7, 4.0);
        t.offer(3, 1.0);
        let hits = t.into_sorted_hits();
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].id, 3);
    }

    #[test]
    fn ties_break_by_id() {
        let mut t = TopK::new(2);
        for id in [9, 4, 6, 1] {
            t.offer(id, 5.0);
        }
        let ids: Vec<usize> = t.into_sorted_hits().iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![1, 4]);
    }

    #[test]
    fn nan_candidates_never_displace_real_hits() {
        let mut t = TopK::new(2);
        t.offer(0, 3.0);
        t.offer(1, 1.0);
        t.offer(2, f64::NAN); // heap full of finite hits: NaN must lose
        t.offer(3, f64::NAN);
        let hits = t.into_sorted_hits();
        let ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![1, 0]);
        assert!(hits.iter().all(|h| h.dist.is_finite()));
    }

    #[test]
    fn nan_sorts_last_when_underfull() {
        // With room to spare a NaN is retained but ordered after every
        // finite distance — the response stays well-formed either way.
        let mut t = TopK::new(3);
        t.offer(0, f64::NAN);
        t.offer(1, 2.0);
        let hits = t.into_sorted_hits();
        assert_eq!(hits[0].id, 1);
        assert!(hits[1].dist.is_nan());
    }

    #[test]
    fn matches_full_sort_on_random_stream() {
        let mut rng = crate::util::rng::Xoshiro256::new(11);
        for k in [1usize, 4, 16] {
            let cands: Vec<(usize, f64)> = (0..200)
                .map(|id| (id, (rng.gen_range(1000) as f64) / 10.0))
                .collect();
            let mut t = TopK::new(k);
            for &(id, d) in &cands {
                t.offer(id, d);
            }
            let mut brute = cands.clone();
            brute.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            brute.truncate(k);
            let got: Vec<(usize, f64)> =
                t.into_sorted_hits().iter().map(|h| (h.id, h.dist)).collect();
            assert_eq!(got, brute, "k={k}");
        }
    }
}
