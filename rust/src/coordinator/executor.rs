//! Persistent shard-executor runtime: one long-lived worker thread per
//! shard, fed by a bounded MPSC work queue.
//!
//! Before this runtime, every `topk`/`topk_batch` scatter spawned
//! `num_shards` fresh OS threads via `std::thread::scope` — thread
//! creation, stack setup and teardown on the latency path of *every*
//! query. Here the workers are spawned once, own their shard for scanning
//! (each holds an `Arc` of its shard's lock, so the executor has no back
//! reference to the store), and serve jobs for the life of the store:
//!
//! ```text
//!   scatter_gather(make)            worker 0 ── recv job ── read-lock shard 0 ── job(&shard)
//!     ├─ queue job per shard ─────► worker 1 ── …                                    │
//!     └─ gather (mpsc, by index) ◄──────────────────────── send (shard_idx, result) ─┘
//! ```
//!
//! Invariants and behaviour:
//!
//! * **Bounded queues**: each worker's queue holds at most `queue_cap`
//!   jobs; a full queue blocks the submitter (backpressure, mirroring the
//!   batcher's bounded-queue policy).
//! * **Graceful drain**: dropping the executor closes every queue sender;
//!   workers finish all *queued* jobs (an `mpsc` receiver keeps yielding
//!   buffered messages after disconnection) and then exit, and the drop
//!   joins them. No queued job is lost on shutdown.
//! * **Panic containment**: a panicking job is caught (`catch_unwind`) so
//!   the worker survives and keeps serving its shard — one bad query must
//!   not wedge every later scatter the way a dead worker with a bounded
//!   queue would. The *caller* of the scatter still observes the failure:
//!   its gather channel sender dies with the job, so the gather panics
//!   with a descriptive message instead of hanging (the pre-executor
//!   scoped-spawn path propagated panics via `join().unwrap()`; this
//!   keeps that contract without sacrificing the worker).
//! * **Observability**: queue depth and busy-worker gauges plus job,
//!   scatter and contained-panic totals land in [`ExecutorCounters`],
//!   surfaced as `executor_*` fields of the `stats` response (a nonzero
//!   `executor_job_panics` means some job crashed and was papered over —
//!   alert on it). Per-shard queue depth and its high-water mark are
//!   tracked for the first `TRACKED_SHARDS` shards
//!   (`executor_queue_hwm_shard{i}` — the hot-shard signal). Panics also
//!   emit a structured `executor/job_panicked` log event.
//! * **Fault injection**: `submit` passes the delay-only
//!   `executor_submit` failpoint (see [`crate::fault`]), so tests can
//!   stall the scatter path and assert it surfaces as a slow op.
//!
//! Lock discipline: a worker takes exactly one lock — its own shard's
//! read lock, via the store's poison-recovering `read_l` — and the
//! submitter takes none, so the executor adds no edges to the store's
//! lock-order graph.

use super::metrics::ExecutorCounters;
use super::store::Shard;
use crate::obs::log as obs_log;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::{Arc, PoisonError, RwLock};

/// A unit of shard work: runs on the shard's worker thread with the shard
/// read-locked.
pub type ShardJob = Box<dyn FnOnce(&Shard) + Send>;

/// Executor construction knobs, carried by `CoordinatorConfig`.
#[derive(Clone, Debug)]
pub struct ExecutorConfig {
    /// Per-shard work-queue bound; submitters block when it is full.
    pub queue_cap: usize,
    /// Where to record queue/busy/job traffic (Arc-shared with
    /// `coordinator::Metrics`).
    pub counters: Arc<ExecutorCounters>,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        Self {
            queue_cap: 1024,
            counters: Arc::new(ExecutorCounters::default()),
        }
    }
}

struct Worker {
    tx: Option<SyncSender<ShardJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// The persistent per-shard worker pool. Owned by the store; all serving
/// scans go through [`ShardExecutor::scatter_gather`].
pub struct ShardExecutor {
    workers: Vec<Worker>,
    counters: Arc<ExecutorCounters>,
}

impl ShardExecutor {
    /// Spawn one worker per shard. Each worker holds its own `Arc` of the
    /// shard lock, so the executor's lifetime is independent of the
    /// store struct that owns it.
    pub fn start(shards: &[Arc<RwLock<Shard>>], config: &ExecutorConfig) -> ShardExecutor {
        let counters = config.counters.clone();
        let workers = shards
            .iter()
            .enumerate()
            .map(|(si, shard)| {
                let (tx, rx) = sync_channel::<ShardJob>(config.queue_cap.max(1));
                let shard = Arc::clone(shard);
                let counters = counters.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("cabin-shard-{si}"))
                    .spawn(move || worker_loop(si, shard, rx, counters))
                    .expect("spawn shard worker");
                Worker {
                    tx: Some(tx),
                    handle: Some(handle),
                }
            })
            .collect();
        ShardExecutor { workers, counters }
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn counters(&self) -> &Arc<ExecutorCounters> {
        &self.counters
    }

    /// Queue one job on shard `si`'s worker. Blocks while the queue is
    /// full (backpressure). Panics if the worker is gone, which can only
    /// happen after the executor started shutting down.
    pub fn submit(&self, si: usize, job: ShardJob) {
        // Delay-only failpoint: an injected sleep here stalls the submit
        // path the way a saturated queue would; an `Err` kind is ignored
        // (there is no error return to surface it through).
        let _ = crate::fault::check("executor_submit");
        let tx = self.workers[si]
            .tx
            .as_ref()
            .expect("executor is shutting down");
        self.counters.queue_depth.fetch_add(1, Ordering::Relaxed);
        self.counters.note_enqueue(si);
        if tx.send(job).is_err() {
            self.counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
            self.counters.note_dequeue(si);
            panic!("shard {si} worker exited with jobs outstanding");
        }
    }

    /// Scatter one job per shard and gather the results in shard order.
    /// `make(si)` builds shard `si`'s job; the job runs under that shard's
    /// read lock on its persistent worker. Blocks until every shard has
    /// answered. Panics (after collecting what it can) if a shard's job
    /// panicked — the same contract the scoped-spawn scatter had via
    /// `join().unwrap()`.
    pub fn scatter_gather<T, F>(&self, mut make: F) -> Vec<T>
    where
        T: Send + 'static,
        F: FnMut(usize) -> Box<dyn FnOnce(&Shard) -> T + Send>,
    {
        self.counters.scatters.fetch_add(1, Ordering::Relaxed);
        let n = self.workers.len();
        let (tx, rx): (_, Receiver<(usize, T)>) = channel();
        for si in 0..n {
            let job = make(si);
            let tx = tx.clone();
            self.submit(
                si,
                Box::new(move |shard| {
                    // if `job` panics, `tx` is dropped without sending and
                    // the gather below notices the missing slot
                    let result = job(shard);
                    let _ = tx.send((si, result));
                }),
            );
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            match rx.recv() {
                Ok((si, result)) => slots[si] = Some(result),
                Err(_) => break, // a job panicked; fall through to the check
            }
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(si, slot)| {
                slot.unwrap_or_else(|| panic!("shard {si} scan job panicked mid-scatter"))
            })
            .collect()
    }
}

impl Drop for ShardExecutor {
    fn drop(&mut self) {
        // Close every queue first so all workers begin draining in
        // parallel, then join them.
        for w in &mut self.workers {
            w.tx = None;
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(
    si: usize,
    shard: Arc<RwLock<Shard>>,
    rx: Receiver<ShardJob>,
    counters: Arc<ExecutorCounters>,
) {
    // recv yields every queued job even after all senders are dropped —
    // this loop IS the graceful drain.
    while let Ok(job) = rx.recv() {
        counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
        counters.note_dequeue(si);
        counters.busy_workers.fetch_add(1, Ordering::Relaxed);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let guard = shard.read().unwrap_or_else(PoisonError::into_inner);
            job(&guard);
        }));
        counters.busy_workers.fetch_sub(1, Ordering::Relaxed);
        counters.jobs.fetch_add(1, Ordering::Relaxed);
        if outcome.is_err() {
            counters.job_panics.fetch_add(1, Ordering::Relaxed);
            obs_log::error(
                "executor",
                "job_panicked",
                &[
                    ("shard", obs_log::V::u(si as u64)),
                    ("recovered", obs_log::V::b(true)),
                ],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchMatrix;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn shards(n: usize) -> Vec<Arc<RwLock<Shard>>> {
        (0..n)
            .map(|_| {
                Arc::new(RwLock::new(Shard {
                    ids: Vec::new(),
                    rows: SketchMatrix::new(64),
                    expiry: Vec::new(),
                    index: None,
                }))
            })
            .collect()
    }

    #[test]
    fn scatter_gather_returns_in_shard_order() {
        let shards = shards(4);
        let ex = ShardExecutor::start(&shards, &ExecutorConfig::default());
        let out = ex.scatter_gather(|si| Box::new(move |_s: &Shard| si * 10));
        assert_eq!(out, vec![0, 10, 20, 30]);
        assert_eq!(ex.counters().scatters.load(Ordering::Relaxed), 1);
        assert_eq!(ex.counters().jobs.load(Ordering::Relaxed), 4);
        assert_eq!(ex.counters().queue_depth.load(Ordering::Relaxed), 0);
        assert_eq!(ex.counters().busy_workers.load(Ordering::Relaxed), 0);
        // per-shard gauges drained, high-water mark retained
        for si in 0..4 {
            assert_eq!(ex.counters().per_shard_depth[si].load(Ordering::Relaxed), 0);
            assert!(ex.counters().per_shard_hwm[si].load(Ordering::Relaxed) >= 1);
        }
    }

    #[test]
    fn drop_drains_every_queued_job() {
        let shards = shards(2);
        let ex = ShardExecutor::start(&shards, &ExecutorConfig::default());
        let ran = Arc::new(AtomicUsize::new(0));
        // queue slow jobs directly (no gather) and drop the executor
        // immediately: shutdown must finish them, not abandon them
        for si in 0..2 {
            for _ in 0..5 {
                let ran = ran.clone();
                ex.submit(
                    si,
                    Box::new(move |_s| {
                        std::thread::sleep(Duration::from_millis(2));
                        ran.fetch_add(1, Ordering::SeqCst);
                    }),
                );
            }
        }
        drop(ex);
        assert_eq!(ran.load(Ordering::SeqCst), 10, "queued jobs lost on drop");
    }

    #[test]
    fn worker_survives_a_panicking_job() {
        let shards = shards(1);
        let ex = ShardExecutor::start(&shards, &ExecutorConfig::default());
        // the scatter must propagate the panic to the caller...
        let poisoned = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.scatter_gather(|_si| Box::new(|_s: &Shard| -> usize { panic!("bad job") }));
        }));
        assert!(poisoned.is_err());
        // ...count the contained panic...
        assert_eq!(
            ex.counters().job_panics.load(Ordering::Relaxed),
            1,
            "panicking job must increment executor_job_panics"
        );
        // ...and the worker must keep serving afterwards
        let out = ex.scatter_gather(|si| Box::new(move |_s: &Shard| si + 7));
        assert_eq!(out, vec![7]);
        assert_eq!(ex.counters().job_panics.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_scatters_share_the_workers() {
        let shards = shards(3);
        let ex = Arc::new(ShardExecutor::start(&shards, &ExecutorConfig::default()));
        std::thread::scope(|scope| {
            for t in 0..8usize {
                let ex = ex.clone();
                scope.spawn(move || {
                    for _ in 0..20 {
                        let out = ex.scatter_gather(|si| Box::new(move |_s: &Shard| si + t));
                        assert_eq!(out, vec![t, t + 1, t + 2]);
                    }
                });
            }
        });
        assert_eq!(
            ex.counters().jobs.load(Ordering::Relaxed),
            8 * 20 * 3,
            "every job accounted"
        );
    }
}
