//! L3 coordinator — a streaming *sketch service* around the Cabin/Cham
//! pipeline, shaped like a serving system: requests arrive over TCP as
//! line-delimited JSON, inserts flow through a deadline/size dynamic
//! batcher into the sketching backend (AOT/XLA when artifacts match the
//! dataset configuration, native bit-packed otherwise), sketches land in
//! point-balanced shard **arenas** (least-loaded by atomically reserved
//! size), and queries — single or batched — scatter/gather across shards
//! for top-k by estimated Hamming distance, either by full arena scan or
//! sublinearly through per-shard multi-probe Hamming-LSH candidate
//! indexes ([`crate::index`]).
//!
//! ```text
//!  TCP conn ─┐                        ┌─ shard 0 ─ SketchMatrix arena ┐
//!  TCP conn ─┼─ protocol ─ batcher ───┼─ shard 1 ─ (row-major u64     ├─ router
//!  TCP conn ─┘      │        │        └─ shard S-1  + weight cache    ┘  (heap top-k,
//!                 metrics   backend        │         + LshIndex)         merge)
//!                    │      (XLA | native) │         + WAL ──────────► data dir:
//!                 id index: id → (shard, row)  L banded bucket tables   MANIFEST
//!                           O(1) get/distance  candidates → Cham rerank snap-G-*
//!                                              (full-scan fallback)     wal-G-*
//! ```
//!
//! Storage layout: each shard owns a [`crate::sketch::SketchMatrix`] — one
//! contiguous row-major `u64` arena plus a cached per-row Hamming weight —
//! so a shard scan is a linear walk over one allocation. The per-shard
//! top-k runs on the bounded max-heap in [`topk`] (one comparison per
//! candidate against the current k-th best, no per-candidate allocation),
//! and a dense global id index resolves `get`/`distance` lookups in O(1).
//! `query_batch` requests amortise shard lock acquisition, worker spawn and
//! per-query `|q̃|` precomputation across a whole batch of queries.
//!
//! Index layer: when [`crate::index::IndexConfig`] enables it (`on`, or
//! `auto` once a shard is large enough), each shard also carries an
//! [`crate::index::LshIndex`] — `L` bands of `b` sampled sketch-bit
//! positions hashed into bucket tables, maintained incrementally under
//! the same shard lock: inserts append, and every rebalance move mirrors
//! its trailing-row pop/append into the two indexes (O(L)). The router
//! gathers bucket candidates (multi-probing the lowest-confidence bits),
//! reranks them with the exact Cham estimate on borrowed arena rows, and
//! falls back to the full heap scan whenever the candidate set cannot
//! guarantee `k` hits or covers most of the shard anyway — so the index
//! can never shrink a result set and never costs more than a small
//! constant over the scan. Traffic is observable via the `index_*`
//! counters and the `index_cfg_*` fields of the `stats` response.
//!
//! Persistence layer ([`crate::persist`], optional via
//! `CoordinatorConfig.persist` / `--data-dir`): each shard's arena is
//! backed by an append-only WAL — length-prefixed, checksummed records
//! appended *under the same shard write lock that mutates the arena*, so
//! log order equals mutation order and every shard recovers independently
//! — plus periodic stop-the-world snapshot rotations (full arena + id
//! column + cached weights per shard, committed by an atomic `MANIFEST`
//! rename, old generation GC'd after). The WAL batch is committed before
//! the batcher acknowledges an insert: with `fsync = always`, an
//! acknowledged insert survives `kill -9`. Recovery invariants: the
//! configuration fingerprint (`sketch_dim`/`seed`/`num_shards`) must match
//! or startup hard-errors (foreign sketches would corrupt every Cham
//! estimate); a torn WAL tail drops only the partial final record (and is
//! truncated to a frame boundary); per-shard LSH indexes are bulk-rebuilt
//! with [`crate::index::LshIndex::rebuild`] over the recovered arenas and
//! answer queries identically to their pre-crash incremental selves. The
//! wire protocol gains `flush` (fsync all WALs now) and `snapshot` (force
//! a rotation) ops, `Shutdown` flushes before acknowledging, and
//! `persist_*` counters ride along in `stats`.
//!
//! Robustness: `k == 0` and malformed batch elements are rejected at the
//! protocol layer with error responses; the top-k kernel itself treats
//! `k == 0` as "no hits" and orders distances with `f64::total_cmp`, so a
//! NaN estimate can neither panic a shard worker nor corrupt the merge.
//! Shard lock acquisition is poison-recovering throughout `store.rs`: a
//! panicking worker thread (the arena's panic-safe mutation ordering keeps
//! the shard readable) can no longer brick every subsequent request.
//!
//! Backpressure: the batcher queue is bounded; when full, submitters block
//! (TCP reads pause → kernel backpressure to clients).
//!
//! Benches: `bench_coordinator` (ingest policies, single + batched query
//! scatter/gather), `bench_topk` (arena+heap shard scan vs the seed's
//! `Vec<BitVec>` insertion-sort scan) and `bench_persist` (WAL/fsync
//! ingest tax, snapshot rotation, WAL-vs-snapshot recovery time).

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;
pub mod store;
pub mod topk;

pub use batcher::{BatcherConfig, SketchBackend};
pub use metrics::{stats_field, IndexCounters, Metrics};
pub use protocol::{Request, Response};
pub use server::{Coordinator, CoordinatorConfig};
pub use topk::TopK;

// The index and persistence knobs travel with the coordinator config;
// re-export them so service users need only one import path.
pub use crate::index::{IndexConfig, IndexMode};
pub use crate::persist::{FsyncPolicy, PersistConfig, PersistMode};
