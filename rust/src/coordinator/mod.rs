//! L3 coordinator — a streaming *sketch service* around the Cabin/Cham
//! pipeline, shaped like a serving system: requests arrive over TCP as
//! line-delimited JSON, inserts flow through a deadline/size dynamic
//! batcher into the sketching backend (AOT/XLA when artifacts match the
//! dataset configuration, native bit-packed otherwise), sketches land in
//! density-balanced shards, and queries scatter/gather across shards for
//! top-k by estimated Hamming distance.
//!
//! ```text
//!  TCP conn ─┐                        ┌─ shard 0 (sketches, ids)
//!  TCP conn ─┼─ protocol ─ batcher ───┼─ shard 1        ─┐
//!  TCP conn ─┘      │        │        └─ shard S-1       ├─ router (top-k merge)
//!                 metrics   backend (XLA | native)      ─┘
//! ```
//!
//! Backpressure: the batcher queue is bounded; when full, submitters block
//! (TCP reads pause → kernel backpressure to clients).

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;
pub mod store;

pub use batcher::{BatcherConfig, SketchBackend};
pub use protocol::{Request, Response};
pub use server::{Coordinator, CoordinatorConfig};
