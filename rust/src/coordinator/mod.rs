//! L3 coordinator — a streaming *sketch service* around the Cabin/Cham
//! pipeline, shaped like a serving system over a **mutable corpus**:
//! requests arrive over TCP as line-delimited JSON, writes — inserts
//! (optionally with a TTL), deletes and upserts — flow through a
//! deadline/size dynamic batcher (one FIFO queue per client, so a
//! client's writes apply in submission order) into the sketching backend
//! (AOT/XLA when artifacts match the dataset configuration, native
//! bit-packed otherwise), sketches land in point-balanced shard
//! **arenas** (least-loaded by atomically reserved size; deletes
//! swap-remove, in-shard upserts overwrite in place), and queries —
//! single or batched — scatter/gather across shards for top-k by
//! estimated Hamming distance, either by full arena scan or sublinearly
//! through per-shard multi-probe Hamming-LSH candidate indexes
//! ([`crate::index`]) maintained incrementally through every mutation.
//!
//! ```text
//!  TCP conn ─┐                        ┌─ shard 0 ─ worker 0 ─ SketchMatrix arena ┐
//!  TCP conn ─┼─ protocol ─ batcher ───┼─ shard 1 ─ worker 1 ─ (row-major u64     ├─ router
//!  TCP conn ─┘      │        │        └─ shard S-1 worker S-1 + weight cache     ┘  (blocked
//!                 metrics   backend     (executor: bounded     + LshIndex)          tile top-k,
//!                    │      (XLA | native)  MPSC queues)       + WAL ───────────►   merge)
//!                 id index: id → (shard, row)  L banded bucket tables   data dir: MANIFEST
//!                           O(1) get/distance  candidates → Cham rerank snap-G-* wal-G-*
//!                                              (full-scan fallback)     + group-commit thread
//! ```
//!
//! Storage layout: each shard owns a [`crate::sketch::SketchMatrix`] — one
//! contiguous row-major `u64` arena plus a cached per-row Hamming weight —
//! so a shard scan is a linear walk over one allocation. The arena is
//! mutable: `delete` swap-removes a row (the last row slides into the
//! hole; the id index and LSH index are patched under the same shard
//! write lock, so readers never observe a half-applied move), `upsert`
//! re-sketches and overwrites in place when the id stays on its shard
//! (delete + fresh placement otherwise), and each row carries an optional
//! absolute expiry deadline swept by a primary-side background task that
//! emits ordinary replicated deletes. The per-shard
//! top-k runs on the bounded max-heap in [`topk`] (one comparison per
//! candidate against the current k-th best, no per-candidate allocation),
//! and a dense global id index resolves `get`/`distance` lookups in O(1).
//!
//! Scan runtime ([`executor`]): every query scatter runs on a persistent
//! shard-executor — one long-lived worker thread per shard behind a
//! bounded MPSC work queue, spawned once at store construction. No
//! serving path spawns threads per request; queue-depth/busy-worker
//! gauges surface as `executor_*` stats fields. Scans are *batch-major*:
//! a `query_batch` ships the whole query block to each worker, which
//! walks its arena once in L1-sized row tiles, scoring every query
//! against each tile via the runtime-dispatched multi-query popcount
//! kernels ([`crate::sketch::SketchMatrix::tile_and_counts`], the widest
//! ISA arm [`crate::sketch::kernels`] detects) — so a Q-query
//! batch pays one arena pass, one scatter and one `|q̃|` precomputation
//! instead of Q of each. Single queries are the Q = 1 case of the same
//! path.
//!
//! Index layer: when [`crate::index::IndexConfig`] enables it (`on`, or
//! `auto` once a shard is large enough), each shard also carries an
//! [`crate::index::LshIndex`] — `L` bands of `b` sampled sketch-bit
//! positions hashed into bucket tables, maintained incrementally under
//! the same shard lock: inserts append, deletes mirror the swap-remove,
//! in-place upserts rehash the changed row, and every rebalance move
//! mirrors its trailing-row pop/append into the two indexes (O(L)). The
//! router
//! gathers bucket candidates (multi-probing the lowest-confidence bits),
//! reranks them with the exact Cham estimate on borrowed arena rows, and
//! falls back to the full heap scan whenever the candidate set cannot
//! guarantee `k` hits or covers most of the shard anyway — so the index
//! can never shrink a result set and never costs more than a small
//! constant over the scan. Traffic is observable via the `index_*`
//! counters and the `index_cfg_*` fields of the `stats` response.
//!
//! Persistence layer ([`crate::persist`], optional via
//! `CoordinatorConfig.persist` / `--data-dir`): each shard's arena is
//! backed by an append-only WAL — length-prefixed, checksummed records
//! appended *under the same shard write lock that mutates the arena*, so
//! log order equals mutation order and every shard recovers independently
//! — the log records *mutations* (insert, insert-with-TTL, delete,
//! upsert, rebalance move), not just appends — plus periodic
//! stop-the-world snapshot rotations (full arena + id column + cached
//! weights + expiry column per shard, committed by an atomic `MANIFEST`
//! rename, old generation GC'd after). Deletes and in-place upserts
//! leave *dead frames* behind; `--compact-dead-frames` makes their count
//! a third rotation trigger, so compaction is just an ordinary snapshot
//! cut that starts the log empty (`persist_wal_dead_frames` /
//! `persist_compactions` stats). The WAL batch is committed before
//! the batcher acknowledges a write: with `fsync = always`, an
//! acknowledged write survives `kill -9`. With a group-commit window
//! configured (`--commit-window-us`, default 1 ms; engaged under
//! `--fsync always`, where there is an fsync to amortise) the fsync
//! itself moves off the ack critical path: appends still happen under
//! the shard lock,
//! but a dedicated group-commit thread coalesces every batch landing in
//! the same window into one fsync per touched shard, and each
//! batch — insert-only or mixed-mutation alike — blocks until its
//! window's commit lands — same "acked ⇒ survives kill -9" contract,
//! amortised fsyncs. A WAL commit *failure* is propagated through the
//! batcher to the client as a write error on the wire (never a
//! logged-warning-plus-ack). Recovery
//! invariants: the configuration fingerprint (`input_dim`/
//! `num_categories`/`sketch_dim`/`seed`/`num_shards`) must match or
//! startup hard-errors (foreign sketches would corrupt every Cham
//! estimate); a torn WAL tail drops only the partial final record (and is
//! truncated to a frame boundary); per-shard LSH indexes are bulk-rebuilt
//! with [`crate::index::LshIndex::rebuild`] over the recovered arenas and
//! answer queries identically to their pre-crash incremental selves. The
//! wire protocol gains `flush` (fsync all WALs now) and `snapshot` (force
//! a rotation) ops, `Shutdown` flushes before acknowledging, and
//! `persist_*` counters ride along in `stats`.
//!
//! Replication layer ([`crate::replica`], `serve --replicate-from`):
//! because every arena mutation is a WAL frame appended under its
//! shard's lock, the log *is* the corpus — so read scale-out is log
//! shipping. Frames carry implicit monotonic per-shard sequence numbers
//! (position + the manifest-v4 per-shard `base_seqs`); a primary serves
//! `repl_snapshot` (verbatim snapshot arenas + seq anchoring) and
//! `repl_wal_tail{shard, from_seq}` (checksummed raw frame ranges) on
//! the same TCP protocol, retaining each rotated-out WAL segment for one
//! generation so followers can lag across a rotation. A follower
//! bootstraps those files into its own data dir, recovers through the
//! ordinary persistence path, applies the live tail of mutations
//! continuously (a feasibility pre-pass rejects a chunk before any
//! mutation lands; frames are mirrored into its own WAL before the
//! cursor advances; paired cross-shard move frames apply destination
//! before source), serves single/batched queries bit-identically to the
//! primary from its own arenas + LSH indexes, rejects writes (`insert`,
//! `delete`, `upsert`) with a redirect, and is flipped writable by the
//! `promote` op — after which writes continue the primary's id/sequence
//! line and the TTL-sweep duty moves with the promotion. Catch-up is
//! observable as
//! `repl_*` stats (per-shard applied seq + lag, caught-up/diverged
//! gauges) and comparable across nodes via `persist_next_seq_shard{i}`.
//!
//! Failover: promotion is safe to automate. A follower started with
//! `--auto-promote` probes its primary (`ping`, configurable interval/
//! timeout/consecutive-failure threshold) and self-promotes once the
//! primary is *dead* — N straight probes missing the budget — never
//! merely slow. Every promotion bumps a monotonic durable **epoch**
//! (manifest v5) that rides mutation acks, pongs and WAL-tail requests;
//! a revived stale primary that hears a higher epoch fences itself
//! read-only behind a durable `FENCED` marker (cleared only by
//! rejoining as a follower via `--replicate-from`), so two writable
//! primaries can never both acknowledge writes. The `demote` op fences
//! by hand; [`MultiClient`] rides the whole scheme from the client side
//! (timeouts, backoff, redirect-following, epoch gossip); and the
//! deterministic fault-injection registry ([`crate::fault`],
//! `CABIN_FAILPOINTS`) plus the chaos suite (`tests/chaos_failover.rs`)
//! exercise partitions, `kill -9` and torn transfers end-to-end. See
//! `docs/FAILOVER.md` for the operational runbook.
//!
//! Ingest pipelining: the batcher *places* a batch (rows + WAL frames +
//! group-commit registration) and hands the fsync-window wait plus the
//! client replies to a completion thread, so it sketches batch N+1 while
//! batch N's window is in flight — replies stay in batch order and the
//! "acked ⇒ survives kill -9" contract is untouched (see [`batcher`]).
//!
//! Robustness: `k == 0` and malformed batch elements are rejected at the
//! protocol layer with error responses; the top-k kernel itself treats
//! `k == 0` as "no hits" and orders distances with `f64::total_cmp`, so a
//! NaN estimate can neither panic a shard worker nor corrupt the merge.
//! Shard lock acquisition is poison-recovering throughout `store.rs`: a
//! panicking worker thread (the arena's panic-safe mutation ordering keeps
//! the shard readable) can no longer brick every subsequent request.
//!
//! Backpressure: the batcher queue is bounded; when full, submitters block
//! (TCP reads pause → kernel backpressure to clients).
//!
//! Observability ([`crate::obs`]): every serving-path latency lands in
//! lock-free fixed-memory [`crate::obs::ObsHistogram`] buckets — no
//! mutex, no allocation on the hot path. One histogram per pipeline
//! stage ([`crate::obs::Stages`], shared via `Metrics`): the write path
//! records batcher queue wait → sketch encode → placement → WAL append →
//! group-commit fsync wait → reply, the read path executor queue wait →
//! scan/kernel → rerank → gather; each surfaces as `stage_*` stats
//! fields (count, p50/p99 ms, cumulative `le_*` bucket counts). Requests
//! carry a per-connection trace id through batcher tickets, and
//! `--slow-op-ms` emits one structured `slow_op` record with the full
//! per-stage breakdown when a request crosses the threshold. Raw
//! `eprintln!` diagnostics are replaced by the leveled text/JSONL event
//! logger (`--log-level`, `--log-json`; [`crate::obs::log`]), and the
//! whole metric surface — counters, gauges, histogram bucket families —
//! is exposed in Prometheus text format by the `metrics_text` wire op
//! ([`crate::obs::prom`], [`client::Client::metrics_text`], `stats
//! --prom` on the CLI), on primaries and followers alike.
//!
//! Benches: `bench_coordinator` (ingest policies, single + batched query
//! scatter/gather), `bench_topk` (arena+heap shard scan vs the seed's
//! `Vec<BitVec>` insertion-sort scan), `bench_router` (executor vs
//! scoped-spawn scatter, blocked vs scalar batch scoring),
//! `bench_persist` (WAL/fsync ingest tax, group-commit coalescing,
//! snapshot rotation, WAL-vs-snapshot recovery time) and
//! `bench_mutation` (delete/upsert throughput, mixed-mutation ingest,
//! compaction-rotation pause).

pub mod batcher;
pub mod client;
pub mod executor;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;
pub mod stats;
pub mod store;
pub mod topk;

pub use batcher::{BatcherConfig, SketchBackend, WriteOp};
pub use client::{Client, ClientConfig, MultiClient};
pub use executor::{ExecutorConfig, ShardExecutor};
pub use metrics::{stats_field, ExecutorCounters, IndexCounters, Metrics};
pub use protocol::{Request, Response, StreamRequest, WriteOpts, WAL_TAIL_DEFAULT_MAX_BYTES};
pub use server::{Coordinator, CoordinatorConfig};
pub use stats::Stats;
pub use topk::TopK;

// The index, persistence and replication knobs travel with the
// coordinator config; re-export them so service users need only one
// import path.
pub use crate::index::{IndexConfig, IndexMode};
pub use crate::persist::{FsyncPolicy, PersistConfig, PersistMode};
pub use crate::replica::{FailoverCounters, ReplCounters, ReplicaConfig};
