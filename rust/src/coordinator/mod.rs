//! L3 coordinator — a streaming *sketch service* around the Cabin/Cham
//! pipeline, shaped like a serving system: requests arrive over TCP as
//! line-delimited JSON, inserts flow through a deadline/size dynamic
//! batcher into the sketching backend (AOT/XLA when artifacts match the
//! dataset configuration, native bit-packed otherwise), sketches land in
//! point-balanced shard **arenas** (least-loaded by atomically reserved
//! size), and queries — single or batched — scatter/gather across shards
//! for top-k by estimated Hamming distance, either by full arena scan or
//! sublinearly through per-shard multi-probe Hamming-LSH candidate
//! indexes ([`crate::index`]).
//!
//! ```text
//!  TCP conn ─┐                        ┌─ shard 0 ─ SketchMatrix arena ┐
//!  TCP conn ─┼─ protocol ─ batcher ───┼─ shard 1 ─ (row-major u64     ├─ router
//!  TCP conn ─┘      │        │        └─ shard S-1  + weight cache    ┘  (heap top-k,
//!                 metrics   backend        │         + LshIndex)         merge)
//!                    │      (XLA | native) └─ L banded bucket tables:
//!                 id index: id → (shard, row)  candidates → Cham rerank
//!                           O(1) get/distance  (full-scan fallback)
//! ```
//!
//! Storage layout: each shard owns a [`crate::sketch::SketchMatrix`] — one
//! contiguous row-major `u64` arena plus a cached per-row Hamming weight —
//! so a shard scan is a linear walk over one allocation. The per-shard
//! top-k runs on the bounded max-heap in [`topk`] (one comparison per
//! candidate against the current k-th best, no per-candidate allocation),
//! and a dense global id index resolves `get`/`distance` lookups in O(1).
//! `query_batch` requests amortise shard lock acquisition, worker spawn and
//! per-query `|q̃|` precomputation across a whole batch of queries.
//!
//! Index layer: when [`crate::index::IndexConfig`] enables it (`on`, or
//! `auto` once a shard is large enough), each shard also carries an
//! [`crate::index::LshIndex`] — `L` bands of `b` sampled sketch-bit
//! positions hashed into bucket tables, maintained incrementally under
//! the same shard lock: inserts append, and every rebalance move mirrors
//! its trailing-row pop/append into the two indexes (O(L)). The router
//! gathers bucket candidates (multi-probing the lowest-confidence bits),
//! reranks them with the exact Cham estimate on borrowed arena rows, and
//! falls back to the full heap scan whenever the candidate set cannot
//! guarantee `k` hits or covers most of the shard anyway — so the index
//! can never shrink a result set and never costs more than a small
//! constant over the scan. Traffic is observable via the `index_*`
//! counters and the `index_cfg_*` fields of the `stats` response.
//!
//! Robustness: `k == 0` and malformed batch elements are rejected at the
//! protocol layer with error responses; the top-k kernel itself treats
//! `k == 0` as "no hits" and orders distances with `f64::total_cmp`, so a
//! NaN estimate can neither panic a shard worker nor corrupt the merge.
//!
//! Backpressure: the batcher queue is bounded; when full, submitters block
//! (TCP reads pause → kernel backpressure to clients).
//!
//! Benches: `bench_coordinator` (ingest policies, single + batched query
//! scatter/gather) and `bench_topk` (arena+heap shard scan vs the seed's
//! `Vec<BitVec>` insertion-sort scan).

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;
pub mod store;
pub mod topk;

pub use batcher::{BatcherConfig, SketchBackend};
pub use metrics::{stats_field, IndexCounters, Metrics};
pub use protocol::{Request, Response};
pub use server::{Coordinator, CoordinatorConfig};
pub use topk::TopK;

// The index knobs travel with the coordinator config; re-export them so
// service users need only one import path.
pub use crate::index::{IndexConfig, IndexMode};
