//! Query router: scatter a query sketch to every shard, compute local
//! top-k by estimated Hamming distance (occupancy-inversion Cham) over the
//! shard's contiguous arena, merge.
//!
//! The per-shard scan borrows arena rows as `&[u64]` and feeds them to the
//! word-slice popcount kernels — no clone, no pointer chase — and selects
//! with the bounded heap in [`super::topk`]: one comparison against the
//! current k-th-best per candidate, O(log k) only on improvement.
//! Candidate weights come from the arena's per-row cache, so each
//! candidate costs exactly one popcount pass (the AND with the query).
//!
//! [`topk_batch`] amortises the scatter: one shard-lock acquisition and one
//! set of spawned workers serve a whole batch of queries, with per-query
//! `|q̃|` precomputed once.

use super::store::{Shard, ShardedStore};
use super::topk::TopK;
use crate::coordinator::protocol::Hit;
use crate::sketch::bitvec::and_count_words;
use crate::sketch::cham::binhamming_from_stats;
use crate::sketch::BitVec;

/// Local top-k on one shard. Returns (id, estimated categorical HD),
/// ascending. `k == 0` returns empty.
fn shard_topk(shard: &Shard, query: &BitVec, wq: f64, k: usize, d: usize) -> Vec<Hit> {
    let mut best = TopK::new(k);
    let query_words = query.words();
    for (row, &id) in shard.ids.iter().enumerate() {
        let ip = and_count_words(query_words, shard.rows.row(row)) as f64;
        let dist = 2.0 * binhamming_from_stats(wq, shard.rows.weight(row) as f64, ip, d);
        best.offer(id, dist);
    }
    best.into_sorted_hits()
}

/// Merge per-shard partials for one query: ascending by `(dist, id)` under
/// the NaN-total order, deduplicated by id, truncated to `k`.
///
/// The dedup covers a scatter racing a `rebalance`: shard workers take
/// their shard locks independently, so a row moved between shards mid-
/// scatter can be scanned by both workers. Its distance is bitwise
/// identical in both (same words, same cached weight, same query), so the
/// duplicates are adjacent after the sort. (The symmetric race — the row
/// scanned by neither worker — means an in-flight query can transiently
/// miss a mid-move candidate; it is never duplicated or corrupted.)
fn merge(partials: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut merged: Vec<Hit> = partials.into_iter().flatten().collect();
    merged.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.id.cmp(&b.id)));
    merged.dedup_by(|a, b| a.id == b.id);
    merged.truncate(k);
    merged
}

/// Scatter/gather top-k across all shards (parallel, one thread per shard).
/// `k == 0` is a no-op returning no hits — never a panic.
pub fn topk(store: &ShardedStore, query: &BitVec, k: usize) -> Vec<Hit> {
    if k == 0 {
        return Vec::new();
    }
    let d = store.sketch_dim();
    let wq = query.count_ones() as f64;
    let partials = store.par_map_shards(|shard| shard_topk(shard, query, wq, k, d));
    merge(partials, k)
}

/// Batched scatter/gather: every shard worker answers all queries in one
/// visit, so shard lock acquisition, thread spawn and the `|q̃|`
/// precomputation are paid once per batch instead of once per query.
/// Returns one ascending hit list per query, in query order.
pub fn topk_batch(store: &ShardedStore, queries: &[BitVec], k: usize) -> Vec<Vec<Hit>> {
    if k == 0 || queries.is_empty() {
        return queries.iter().map(|_| Vec::new()).collect();
    }
    let d = store.sketch_dim();
    let wqs: Vec<f64> = queries.iter().map(|q| q.count_ones() as f64).collect();
    // per_shard[s][q] = shard s's top-k for query q
    let mut per_shard: Vec<Vec<Vec<Hit>>> = store.par_map_shards(|shard| {
        queries
            .iter()
            .zip(&wqs)
            .map(|(q, &wq)| shard_topk(shard, q, wq, k, d))
            .collect()
    });
    (0..queries.len())
        .map(|qi| {
            // move each shard's partial out rather than cloning it
            merge(
                per_shard
                    .iter_mut()
                    .map(|shard| std::mem::take(&mut shard[qi]))
                    .collect(),
                k,
            )
        })
        .collect()
}

/// Estimated distance between two stored points — O(1) id resolution via
/// the store's index, computed on borrowed arena rows.
pub fn distance(store: &ShardedStore, a: usize, b: usize) -> Option<f64> {
    let (wa, wb, ip) = store.pair_stats(a, b)?;
    Some(2.0 * binhamming_from_stats(wa as f64, wb as f64, ip as f64, store.sketch_dim()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn store_with(points: &[BitVec]) -> ShardedStore {
        let store = ShardedStore::new(3, points[0].len());
        for p in points.chunks(4) {
            store.insert_batch(p.to_vec());
        }
        store
    }

    #[test]
    fn topk_finds_the_planted_neighbour() {
        let mut rng = Xoshiro256::new(1);
        let d = 256;
        let mut pts: Vec<BitVec> = (0..40)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        // plant a near-duplicate of the query at id 7
        let query = BitVec::from_indices(d, rng.sample_indices(d, 40));
        let mut near = query.clone();
        near.set(0);
        pts[7] = near;
        let store = store_with(&pts);
        let hits = topk(&store, &query, 5);
        assert_eq!(hits.len(), 5);
        assert_eq!(hits[0].id, 7, "{hits:?}");
        // results sorted ascending
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn topk_k_larger_than_corpus() {
        let mut rng = Xoshiro256::new(2);
        let pts: Vec<BitVec> = (0..3)
            .map(|_| BitVec::from_indices(64, rng.sample_indices(64, 10)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 10);
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn topk_k_zero_returns_empty_not_panic() {
        // Regression: the seed kernel indexed hits[k - 1] and underflowed,
        // killing the shard worker and the coordinator with it.
        let mut rng = Xoshiro256::new(6);
        let pts: Vec<BitVec> = (0..10)
            .map(|_| BitVec::from_indices(64, rng.sample_indices(64, 10)))
            .collect();
        let store = store_with(&pts);
        assert!(topk(&store, &pts[0], 0).is_empty());
        let batched = topk_batch(&store, &pts[..3], 0);
        assert_eq!(batched.len(), 3);
        assert!(batched.iter().all(|h| h.is_empty()));
    }

    #[test]
    fn router_never_drops_or_duplicates() {
        let mut rng = Xoshiro256::new(3);
        let pts: Vec<BitVec> = (0..25)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 25);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn batched_queries_match_single_queries() {
        let mut rng = Xoshiro256::new(5);
        let d = 128;
        let pts: Vec<BitVec> = (0..30)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 20)))
            .collect();
        let store = store_with(&pts);
        let queries: Vec<BitVec> = (0..7)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 20)))
            .collect();
        let batched = topk_batch(&store, &queries, 4);
        assert_eq!(batched.len(), queries.len());
        for (q, batch_hits) in queries.iter().zip(&batched) {
            let single = topk(&store, q, 4);
            assert_eq!(&single, batch_hits);
        }
    }

    #[test]
    fn distance_self_is_zero() {
        let mut rng = Xoshiro256::new(4);
        let pts: Vec<BitVec> = (0..4)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 25)))
            .collect();
        let store = store_with(&pts);
        assert_eq!(distance(&store, 0, 0), Some(0.0));
        assert!(distance(&store, 0, 99).is_none());
        let d01 = distance(&store, 0, 1).unwrap();
        let d10 = distance(&store, 1, 0).unwrap();
        assert!((d01 - d10).abs() < 1e-9);
    }

    #[test]
    fn merge_dedups_a_row_seen_by_two_shards() {
        // mid-rebalance a moved row can be scanned by both its old and new
        // shard; both see identical (id, dist) and the merge must keep one
        let partials = vec![
            vec![Hit { id: 4, dist: 1.5 }, Hit { id: 0, dist: 2.0 }],
            vec![Hit { id: 4, dist: 1.5 }, Hit { id: 9, dist: 3.0 }],
        ];
        let merged = merge(partials, 3);
        let ids: Vec<usize> = merged.iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![4, 0, 9]);
    }

    #[test]
    fn merge_is_nan_safe() {
        // Adversarial partials containing NaN distances must merge without
        // panicking, with NaN ordered after every finite hit.
        let partials = vec![
            vec![
                Hit { id: 0, dist: 2.0 },
                Hit {
                    id: 1,
                    dist: f64::NAN,
                },
            ],
            vec![Hit { id: 2, dist: 1.0 }],
        ];
        let merged = merge(partials, 3);
        assert_eq!(merged[0].id, 2);
        assert_eq!(merged[1].id, 0);
        assert!(merged[2].dist.is_nan());
    }
}
