//! Query router: scatter a query sketch to every shard, compute local
//! top-k by estimated Hamming distance (occupancy-inversion Cham), merge.
//!
//! Two per-shard scan paths, chosen by [`QueryOpts`]:
//!
//! * **Full scan** — walk the shard's contiguous arena. Rows are borrowed
//!   as `&[u64]` and fed to the word-slice popcount kernels — no clone, no
//!   pointer chase — and selected with the bounded heap in [`super::topk`]:
//!   one comparison against the current k-th-best per candidate, O(log k)
//!   only on improvement. Candidate weights come from the arena's per-row
//!   cache, so each candidate costs exactly one popcount pass.
//! * **Indexed** — when the shard carries an [`crate::index::LshIndex`]
//!   and holds at least `min_rows_for_index` rows, gather candidate rows
//!   from the index's banded multi-probe buckets and rerank only those
//!   with the exact Cham estimate (same borrowed-row kernel). If the
//!   candidate set cannot guarantee `min(k, rows)` hits — or covers more
//!   than half the shard, where reranking would cost more than scanning —
//!   the shard *falls back* to the full scan, so an indexed query never
//!   returns fewer hits than an unindexed one and never pays more than a
//!   small constant over the scan: the index can only trade recall inside
//!   the top-k, never result count.
//!
//! [`topk_batch`] amortises the scatter: one shard-lock acquisition and one
//! set of spawned workers serve a whole batch of queries, with per-query
//! `|q̃|` precomputed once.

use super::metrics::IndexCounters;
use super::store::{Shard, ShardedStore};
use super::topk::TopK;
use crate::coordinator::protocol::Hit;
use crate::sketch::bitvec::and_count_words;
use crate::sketch::cham::binhamming_from_stats;
use crate::sketch::BitVec;
use std::sync::atomic::Ordering;

/// Per-query routing options: whether (and from what shard size) to use
/// the shard LSH indexes, and where to record index traffic.
#[derive(Clone, Copy)]
pub struct QueryOpts<'a> {
    /// Use a shard's index only when it holds at least this many rows.
    /// `usize::MAX` never uses the index (the pre-index behaviour), `0`
    /// always does. Derive from `IndexConfig::min_rows_for_index()`.
    pub min_rows_for_index: usize,
    /// Index counters to record probe/candidate/fallback traffic into.
    pub counters: Option<&'a IndexCounters>,
}

impl<'a> QueryOpts<'a> {
    /// Full-scan only — the exact, O(corpus) path.
    pub fn full_scan() -> Self {
        Self {
            min_rows_for_index: usize::MAX,
            counters: None,
        }
    }

    /// Use shard indexes wherever present on shards with ≥ `min_rows`
    /// rows, recording traffic into `counters` when provided.
    pub fn indexed(min_rows: usize, counters: Option<&'a IndexCounters>) -> Self {
        Self {
            min_rows_for_index: min_rows,
            counters,
        }
    }
}

/// Cham-score the given arena rows of one shard against the query and keep
/// the best `k` — the single scoring kernel shared by the full scan (all
/// rows) and the indexed rerank (candidate rows), so the two paths can
/// never drift in distance semantics.
fn score_rows(
    shard: &Shard,
    rows: impl Iterator<Item = usize>,
    query_words: &[u64],
    wq: f64,
    k: usize,
    d: usize,
) -> Vec<Hit> {
    let mut best = TopK::new(k);
    for row in rows {
        let ip = and_count_words(query_words, shard.rows.row(row)) as f64;
        let dist = 2.0 * binhamming_from_stats(wq, shard.rows.weight(row) as f64, ip, d);
        best.offer(shard.ids[row], dist);
    }
    best.into_sorted_hits()
}

/// Local top-k on one shard (full scan). Returns (id, estimated
/// categorical HD), ascending. `k == 0` returns empty.
fn shard_topk(shard: &Shard, query: &BitVec, wq: f64, k: usize, d: usize) -> Vec<Hit> {
    score_rows(shard, 0..shard.ids.len(), query.words(), wq, k, d)
}

/// Local top-k on one shard through the LSH index when present and
/// warranted: generate candidates, rerank them with the exact Cham
/// estimate on borrowed arena rows, and fall back to the full heap scan
/// whenever the candidate set cannot guarantee `min(k, rows)` hits — or
/// covers more than half the shard, where candidate generation plus a
/// near-full rerank would be strictly slower than the plain arena walk
/// (duplicate-heavy or single-cluster corpora collapse into one bucket).
fn shard_topk_with(
    shard: &Shard,
    query: &BitVec,
    wq: f64,
    k: usize,
    d: usize,
    opts: &QueryOpts,
) -> Vec<Hit> {
    let rows = shard.ids.len();
    if let Some(ix) = shard.index.as_ref() {
        if rows >= opts.min_rows_for_index {
            let (cands, probes) = ix.candidates(query.words());
            if let Some(c) = opts.counters {
                c.probes.fetch_add(probes as u64, Ordering::Relaxed);
                c.candidates.fetch_add(cands.len() as u64, Ordering::Relaxed);
            }
            let covers_k = cands.len() >= k.min(rows);
            let beats_scan = cands.len() * 2 <= rows;
            if covers_k && beats_scan {
                if let Some(c) = opts.counters {
                    c.indexed_scans.fetch_add(1, Ordering::Relaxed);
                    c.reranked.fetch_add(cands.len() as u64, Ordering::Relaxed);
                }
                return score_rows(
                    shard,
                    cands.iter().map(|&r| r as usize),
                    query.words(),
                    wq,
                    k,
                    d,
                );
            }
            if let Some(c) = opts.counters {
                c.fallbacks.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    shard_topk(shard, query, wq, k, d)
}

/// Merge per-shard partials for one query: ascending by `(dist, id)` under
/// the NaN-total order, deduplicated by id, truncated to `k`.
///
/// The dedup covers a scatter racing a `rebalance`: shard workers take
/// their shard locks independently, so a row moved between shards mid-
/// scatter can be scanned by both workers. Its distance is bitwise
/// identical in both (same words, same cached weight, same query), so the
/// duplicates are adjacent after the sort. (The symmetric race — the row
/// scanned by neither worker — means an in-flight query can transiently
/// miss a mid-move candidate; it is never duplicated or corrupted.)
fn merge(partials: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut merged: Vec<Hit> = partials.into_iter().flatten().collect();
    merged.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.id.cmp(&b.id)));
    merged.dedup_by(|a, b| a.id == b.id);
    merged.truncate(k);
    merged
}

/// Scatter/gather top-k across all shards (parallel, one thread per shard),
/// full-scan only. `k == 0` is a no-op returning no hits — never a panic.
pub fn topk(store: &ShardedStore, query: &BitVec, k: usize) -> Vec<Hit> {
    topk_with(store, query, k, &QueryOpts::full_scan())
}

/// Scatter/gather top-k with explicit routing options (the coordinator's
/// entry point: index on/auto/off comes in through `opts`).
pub fn topk_with(store: &ShardedStore, query: &BitVec, k: usize, opts: &QueryOpts) -> Vec<Hit> {
    if k == 0 {
        return Vec::new();
    }
    let d = store.sketch_dim();
    let wq = query.count_ones() as f64;
    let partials = store.par_map_shards(|shard| shard_topk_with(shard, query, wq, k, d, opts));
    merge(partials, k)
}

/// Batched scatter/gather: every shard worker answers all queries in one
/// visit, so shard lock acquisition, thread spawn and the `|q̃|`
/// precomputation are paid once per batch instead of once per query.
/// Returns one ascending hit list per query, in query order. Full-scan
/// only; the coordinator uses [`topk_batch_with`].
pub fn topk_batch(store: &ShardedStore, queries: &[BitVec], k: usize) -> Vec<Vec<Hit>> {
    topk_batch_with(store, queries, k, &QueryOpts::full_scan())
}

/// Batched scatter/gather with explicit routing options.
pub fn topk_batch_with(
    store: &ShardedStore,
    queries: &[BitVec],
    k: usize,
    opts: &QueryOpts,
) -> Vec<Vec<Hit>> {
    if k == 0 || queries.is_empty() {
        return queries.iter().map(|_| Vec::new()).collect();
    }
    let d = store.sketch_dim();
    let wqs: Vec<f64> = queries.iter().map(|q| q.count_ones() as f64).collect();
    // per_shard[s][q] = shard s's top-k for query q
    let mut per_shard: Vec<Vec<Vec<Hit>>> = store.par_map_shards(|shard| {
        queries
            .iter()
            .zip(&wqs)
            .map(|(q, &wq)| shard_topk_with(shard, q, wq, k, d, opts))
            .collect()
    });
    (0..queries.len())
        .map(|qi| {
            // move each shard's partial out rather than cloning it
            merge(
                per_shard
                    .iter_mut()
                    .map(|shard| std::mem::take(&mut shard[qi]))
                    .collect(),
                k,
            )
        })
        .collect()
}

/// Estimated distance between two stored points — O(1) id resolution via
/// the store's index, computed on borrowed arena rows.
pub fn distance(store: &ShardedStore, a: usize, b: usize) -> Option<f64> {
    let (wa, wb, ip) = store.pair_stats(a, b)?;
    Some(2.0 * binhamming_from_stats(wa as f64, wb as f64, ip as f64, store.sketch_dim()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn store_with(points: &[BitVec]) -> ShardedStore {
        let store = ShardedStore::new(3, points[0].len());
        for p in points.chunks(4) {
            store.insert_batch(p.to_vec());
        }
        store
    }

    #[test]
    fn topk_finds_the_planted_neighbour() {
        let mut rng = Xoshiro256::new(1);
        let d = 256;
        let mut pts: Vec<BitVec> = (0..40)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        // plant a near-duplicate of the query at id 7
        let query = BitVec::from_indices(d, rng.sample_indices(d, 40));
        let mut near = query.clone();
        near.set(0);
        pts[7] = near;
        let store = store_with(&pts);
        let hits = topk(&store, &query, 5);
        assert_eq!(hits.len(), 5);
        assert_eq!(hits[0].id, 7, "{hits:?}");
        // results sorted ascending
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn topk_k_larger_than_corpus() {
        let mut rng = Xoshiro256::new(2);
        let pts: Vec<BitVec> = (0..3)
            .map(|_| BitVec::from_indices(64, rng.sample_indices(64, 10)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 10);
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn topk_k_zero_returns_empty_not_panic() {
        // Regression: the seed kernel indexed hits[k - 1] and underflowed,
        // killing the shard worker and the coordinator with it.
        let mut rng = Xoshiro256::new(6);
        let pts: Vec<BitVec> = (0..10)
            .map(|_| BitVec::from_indices(64, rng.sample_indices(64, 10)))
            .collect();
        let store = store_with(&pts);
        assert!(topk(&store, &pts[0], 0).is_empty());
        let batched = topk_batch(&store, &pts[..3], 0);
        assert_eq!(batched.len(), 3);
        assert!(batched.iter().all(|h| h.is_empty()));
    }

    #[test]
    fn router_never_drops_or_duplicates() {
        let mut rng = Xoshiro256::new(3);
        let pts: Vec<BitVec> = (0..25)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 25);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn batched_queries_match_single_queries() {
        let mut rng = Xoshiro256::new(5);
        let d = 128;
        let pts: Vec<BitVec> = (0..30)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 20)))
            .collect();
        let store = store_with(&pts);
        let queries: Vec<BitVec> = (0..7)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 20)))
            .collect();
        let batched = topk_batch(&store, &queries, 4);
        assert_eq!(batched.len(), queries.len());
        for (q, batch_hits) in queries.iter().zip(&batched) {
            let single = topk(&store, q, 4);
            assert_eq!(&single, batch_hits);
        }
    }

    #[test]
    fn distance_self_is_zero() {
        let mut rng = Xoshiro256::new(4);
        let pts: Vec<BitVec> = (0..4)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 25)))
            .collect();
        let store = store_with(&pts);
        assert_eq!(distance(&store, 0, 0), Some(0.0));
        assert!(distance(&store, 0, 99).is_none());
        let d01 = distance(&store, 0, 1).unwrap();
        let d10 = distance(&store, 1, 0).unwrap();
        assert!((d01 - d10).abs() < 1e-9);
    }

    fn indexed_store_with(points: &[BitVec]) -> ShardedStore {
        let cfg = crate::index::IndexConfig {
            mode: crate::index::IndexMode::On,
            ..Default::default()
        };
        let store = ShardedStore::with_index(3, points[0].len(), &cfg, 17);
        for p in points.chunks(4) {
            store.insert_batch(p.to_vec());
        }
        store
    }

    #[test]
    fn indexed_topk_finds_the_planted_neighbour() {
        let mut rng = Xoshiro256::new(31);
        let d = 256;
        let mut pts: Vec<BitVec> = (0..60)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        let query = BitVec::from_indices(d, rng.sample_indices(d, 40));
        let mut near = query.clone();
        near.set(0);
        pts[13] = near;
        let store = indexed_store_with(&pts);
        let hits = topk_with(&store, &query, 5, &QueryOpts::indexed(0, None));
        assert_eq!(hits.len(), 5, "fallback must guarantee k hits");
        assert_eq!(hits[0].id, 13, "{hits:?}");
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn indexed_fallback_guarantees_full_result_count() {
        // k larger than any plausible candidate set: every shard must fall
        // back and the indexed path must return exactly min(k, n) hits.
        let mut rng = Xoshiro256::new(32);
        let pts: Vec<BitVec> = (0..25)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = indexed_store_with(&pts);
        let counters = IndexCounters::default();
        let opts = QueryOpts::indexed(0, Some(&counters));
        let hits = topk_with(&store, &pts[0], 25, &opts);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..25).collect::<Vec<_>>());
        assert!(counters.probes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn indexed_batch_matches_indexed_single() {
        let mut rng = Xoshiro256::new(33);
        let d = 256;
        let pts: Vec<BitVec> = (0..40)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        let store = indexed_store_with(&pts);
        let opts = QueryOpts::indexed(0, None);
        let queries: Vec<BitVec> = pts[..6].to_vec();
        let batched = topk_batch_with(&store, &queries, 4, &opts);
        for (q, batch_hits) in queries.iter().zip(&batched) {
            assert_eq!(&topk_with(&store, q, 4, &opts), batch_hits);
        }
    }

    #[test]
    fn min_rows_threshold_gates_the_index_path() {
        let mut rng = Xoshiro256::new(34);
        let pts: Vec<BitVec> = (0..30)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = indexed_store_with(&pts);
        // threshold above every shard size → pure full scan, no counters
        let counters = IndexCounters::default();
        let opts = QueryOpts::indexed(1_000_000, Some(&counters));
        let gated = topk_with(&store, &pts[0], 5, &opts);
        assert_eq!(gated, topk(&store, &pts[0], 5));
        assert_eq!(counters.probes.load(Ordering::Relaxed), 0);
        assert_eq!(counters.fallbacks.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn counters_account_every_indexed_shard_scan() {
        let mut rng = Xoshiro256::new(35);
        let pts: Vec<BitVec> = (0..45)
            .map(|_| BitVec::from_indices(256, rng.sample_indices(256, 40)))
            .collect();
        let store = indexed_store_with(&pts);
        let counters = IndexCounters::default();
        let opts = QueryOpts::indexed(0, Some(&counters));
        let _ = topk_with(&store, &pts[7], 3, &opts);
        let scans = counters.indexed_scans.load(Ordering::Relaxed)
            + counters.fallbacks.load(Ordering::Relaxed);
        assert_eq!(scans, store.num_shards() as u64);
        assert!(counters.probes.load(Ordering::Relaxed) >= scans);
    }

    #[test]
    fn merge_dedups_a_row_seen_by_two_shards() {
        // mid-rebalance a moved row can be scanned by both its old and new
        // shard; both see identical (id, dist) and the merge must keep one
        let partials = vec![
            vec![Hit { id: 4, dist: 1.5 }, Hit { id: 0, dist: 2.0 }],
            vec![Hit { id: 4, dist: 1.5 }, Hit { id: 9, dist: 3.0 }],
        ];
        let merged = merge(partials, 3);
        let ids: Vec<usize> = merged.iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![4, 0, 9]);
    }

    #[test]
    fn merge_is_nan_safe() {
        // Adversarial partials containing NaN distances must merge without
        // panicking, with NaN ordered after every finite hit.
        let partials = vec![
            vec![
                Hit { id: 0, dist: 2.0 },
                Hit {
                    id: 1,
                    dist: f64::NAN,
                },
            ],
            vec![Hit { id: 2, dist: 1.0 }],
        ];
        let merged = merge(partials, 3);
        assert_eq!(merged[0].id, 2);
        assert_eq!(merged[1].id, 0);
        assert!(merged[2].dist.is_nan());
    }
}
