//! Query router: scatter a query sketch to every shard, compute local
//! top-k by estimated Hamming distance (occupancy-inversion Cham), merge.

use super::store::{Shard, ShardedStore};
use crate::coordinator::protocol::Hit;
use crate::sketch::cham::binhamming_from_stats;
use crate::sketch::BitVec;

/// Local top-k on one shard. Returns (id, estimated categorical HD).
fn shard_topk(shard: &Shard, query: &BitVec, wq: f64, k: usize, d: usize) -> Vec<Hit> {
    let mut hits: Vec<Hit> = Vec::with_capacity(shard.ids.len().min(k + 1));
    for (id, sk) in shard.ids.iter().zip(&shard.sketches) {
        let ip = query.and_count(sk) as f64;
        let dist = 2.0 * binhamming_from_stats(wq, sk.count_ones() as f64, ip, d);
        // keep a bounded sorted buffer (k is small; insertion sort wins)
        if hits.len() < k {
            hits.push(Hit { id: *id, dist });
            hits.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        } else if dist < hits[k - 1].dist {
            hits[k - 1] = Hit { id: *id, dist };
            hits.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        }
    }
    hits
}

/// Scatter/gather top-k across all shards (parallel, one thread per shard).
pub fn topk(store: &ShardedStore, query: &BitVec, k: usize) -> Vec<Hit> {
    let d = store.sketch_dim();
    let wq = query.count_ones() as f64;
    let partials = store.par_map_shards(|shard| shard_topk(shard, query, wq, k, d));
    let mut merged: Vec<Hit> = partials.into_iter().flatten().collect();
    merged.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.id.cmp(&b.id)));
    merged.truncate(k);
    merged
}

/// Estimated distance between two stored points.
pub fn distance(store: &ShardedStore, a: usize, b: usize) -> Option<f64> {
    let (sa, sb) = (store.get(a)?, store.get(b)?);
    Some(2.0 * binhamming_from_stats(
        sa.count_ones() as f64,
        sb.count_ones() as f64,
        sa.and_count(&sb) as f64,
        store.sketch_dim(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn store_with(points: &[BitVec]) -> ShardedStore {
        let store = ShardedStore::new(3, points[0].len());
        for p in points.chunks(4) {
            store.insert_batch(p.to_vec());
        }
        store
    }

    #[test]
    fn topk_finds_the_planted_neighbour() {
        let mut rng = Xoshiro256::new(1);
        let d = 256;
        let mut pts: Vec<BitVec> = (0..40)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        // plant a near-duplicate of the query at id 7
        let query = BitVec::from_indices(d, rng.sample_indices(d, 40));
        let mut near = query.clone();
        near.set(0);
        pts[7] = near;
        let store = store_with(&pts);
        let hits = topk(&store, &query, 5);
        assert_eq!(hits.len(), 5);
        assert_eq!(hits[0].id, 7, "{hits:?}");
        // results sorted ascending
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn topk_k_larger_than_corpus() {
        let mut rng = Xoshiro256::new(2);
        let pts: Vec<BitVec> = (0..3)
            .map(|_| BitVec::from_indices(64, rng.sample_indices(64, 10)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 10);
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn router_never_drops_or_duplicates() {
        let mut rng = Xoshiro256::new(3);
        let pts: Vec<BitVec> = (0..25)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 25);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn distance_self_is_zero() {
        let mut rng = Xoshiro256::new(4);
        let pts: Vec<BitVec> = (0..4)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 25)))
            .collect();
        let store = store_with(&pts);
        assert_eq!(distance(&store, 0, 0), Some(0.0));
        assert!(distance(&store, 0, 99).is_none());
        let d01 = distance(&store, 0, 1).unwrap();
        let d10 = distance(&store, 1, 0).unwrap();
        assert!((d01 - d10).abs() < 1e-9);
    }
}
