//! Query router: scatter a query batch to every shard over the store's
//! persistent executor, compute local top-k by estimated Hamming distance
//! (occupancy-inversion Cham), merge.
//!
//! Execution model: one job per shard is queued on the store's
//! [`crate::coordinator::executor::ShardExecutor`] — long-lived workers,
//! no thread spawned per request — and each job answers *all* queries of
//! the batch in one shard visit (`topk` is the Q = 1 case of the same
//! path, so single and batched queries can never drift).
//!
//! Two per-shard scan paths, chosen by [`QueryOpts`]:
//!
//! * **Blocked full scan** — walk the shard's contiguous arena in tiles
//!   of [`crate::sketch::SketchMatrix::tile_rows`] rows (sized to keep a
//!   tile resident in L1), scoring every query of the batch against each
//!   tile via the runtime-dispatched multi-query popcount kernel
//!   ([`SketchMatrix::tile_and_counts`], the widest ISA arm
//!   [`crate::sketch::kernels`] detected) before moving to the next tile:
//!   batch-major, so a Q-query batch streams the arena once instead of Q
//!   times. Candidates feed the bounded heap in [`super::topk`] (one
//!   comparison against the current k-th-best per candidate); candidate
//!   weights come from the arena's per-row cache.
//! * **Indexed** — when the shard carries an [`crate::index::LshIndex`]
//!   and holds at least `min_rows_for_index` rows, gather candidate rows
//!   from the index's banded multi-probe buckets per query and rerank
//!   only those with the exact Cham estimate, via the same dispatched
//!   kernel in its gathered form ([`SketchMatrix::gather_and_counts`]).
//!   Queries whose candidate set cannot guarantee `min(k, rows)` hits —
//!   or covers more than half the shard, where reranking would cost more
//!   than scanning — *fall back* and join the blocked full scan of the
//!   remaining batch, so an indexed query never returns fewer hits than
//!   an unindexed one.
//!
//! Both paths produce bit-for-bit the distances of the scalar oracle
//! kernel ([`crate::sketch::kernels::scalar`] — integer popcounts; the
//! SIMD arms and blocked traversal change evaluation order, never the
//! counts), so indexed rerank, blocked scan and the pre-blocking scalar
//! scan agree exactly on every ISA.
//!
//! [`topk_batch`] amortises the scatter: one executor job per shard and
//! one arena pass serve a whole batch of queries, with per-query `|q̃|`
//! precomputed once.

use super::metrics::IndexCounters;
use super::store::{Shard, ShardedStore};
use super::topk::TopK;
use crate::coordinator::protocol::Hit;
use crate::obs::{self, ReadSpan, Stages};
use crate::sketch::cham::binhamming_from_stats;
use crate::sketch::{BitVec, SketchMatrix};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Per-query routing options: whether (and from what shard size) to use
/// the shard LSH indexes, and where to record index traffic. Counters are
/// `Arc`-shared (not borrowed) because the scan jobs run on the store's
/// persistent worker threads, which outlive any caller's stack frame.
// No derived Default: it would yield `min_rows_for_index = 0` ("always
// use the index"), the opposite of the safe [`QueryOpts::full_scan`]
// neutral. Construct explicitly.
#[derive(Clone)]
pub struct QueryOpts {
    /// Use a shard's index only when it holds at least this many rows.
    /// `usize::MAX` never uses the index (the pre-index behaviour), `0`
    /// always does. Derive from `IndexConfig::min_rows_for_index()`.
    pub min_rows_for_index: usize,
    /// Index counters to record probe/candidate/fallback traffic into.
    pub counters: Option<Arc<IndexCounters>>,
    /// Read-path stage histograms (`stage_read_*`): executor queue wait,
    /// scan/kernel, rerank, gather. `None` (library/bench callers) skips
    /// all stage timing.
    pub stages: Option<Arc<Stages>>,
    /// Per-request critical-path span for slow-op records: each read
    /// stage keeps its max across the parallel shard jobs. `None` skips.
    pub span: Option<Arc<ReadSpan>>,
}

impl QueryOpts {
    /// Full-scan only — the exact, O(corpus) path.
    pub fn full_scan() -> Self {
        Self {
            min_rows_for_index: usize::MAX,
            counters: None,
            stages: None,
            span: None,
        }
    }

    /// Use shard indexes wherever present on shards with ≥ `min_rows`
    /// rows, recording traffic into `counters` when provided.
    pub fn indexed(min_rows: usize, counters: Option<Arc<IndexCounters>>) -> Self {
        Self {
            min_rows_for_index: min_rows,
            counters,
            stages: None,
            span: None,
        }
    }

    /// Attach stage histograms and (optionally) a per-request span —
    /// the server's serving path sets both; benches set only `stages`
    /// when measuring instrumentation overhead.
    pub fn with_observer(mut self, stages: Arc<Stages>, span: Option<Arc<ReadSpan>>) -> Self {
        self.stages = Some(stages);
        self.span = span;
        self
    }
}

/// Everything a shard scan job needs, bundled once per request and
/// `Arc`-shared across the per-shard executor jobs.
struct ScatterCtx {
    queries: Vec<BitVec>,
    /// Per-query `|q̃|`, precomputed once per request.
    wqs: Vec<f64>,
    k: usize,
    d: usize,
    opts: QueryOpts,
}

#[inline]
fn cham_dist(wq: f64, weight: usize, ip: usize, d: usize) -> f64 {
    2.0 * binhamming_from_stats(wq, weight as f64, ip as f64, d)
}

/// Blocked batch-major full scan: all `sel` queries of the batch against
/// every arena row, tile by tile — each tile of rows is pulled into cache
/// once and scored against the whole query block via the dispatched
/// multi-query kernel. Appends each query's hits into its heap in arena
/// row order (the same offer order as a scalar per-query walk, so results
/// are bit-for-bit identical to the pre-blocking path).
fn blocked_full_scan(shard: &Shard, ctx: &ScatterCtx, sel: &[usize], heaps: &mut [TopK]) {
    debug_assert_eq!(sel.len(), heaps.len());
    let rows: &SketchMatrix = &shard.rows;
    let n = rows.len();
    if n == 0 || sel.is_empty() {
        return;
    }
    let qwords: Vec<&[u64]> = sel.iter().map(|&qi| ctx.queries[qi].words()).collect();
    let tile = rows.tile_rows();
    let mut counts = vec![0usize; tile * qwords.len()];
    let mut start = 0;
    while start < n {
        let end = (start + tile).min(n);
        let len = end - start;
        let counts = &mut counts[..len * qwords.len()];
        rows.tile_and_counts(&qwords, start, end, counts);
        for (si, (&qi, heap)) in sel.iter().zip(heaps.iter_mut()).enumerate() {
            let wq = ctx.wqs[qi];
            let base = si * len;
            for i in 0..len {
                let row = start + i;
                let dist = cham_dist(wq, rows.weight(row), counts[base + i], ctx.d);
                heap.offer(shard.ids[row], dist);
            }
        }
        start = end;
    }
}

/// Indexed rerank of one query's candidate rows, via the gathered form of
/// the same dispatched kernel the blocked scan uses.
fn rerank_candidates(shard: &Shard, ctx: &ScatterCtx, qi: usize, cands: &[u32]) -> Vec<Hit> {
    let mut counts = vec![0usize; cands.len()];
    shard
        .rows
        .gather_and_counts(ctx.queries[qi].words(), cands, &mut counts);
    let mut best = TopK::new(ctx.k);
    for (&row, &ip) in cands.iter().zip(&counts) {
        let dist = cham_dist(ctx.wqs[qi], shard.rows.weight(row as usize), ip, ctx.d);
        best.offer(shard.ids[row as usize], dist);
    }
    best.into_sorted_hits()
}

/// One shard's answers for every query of the batch: route each query
/// through the LSH index when present and warranted, and run one blocked
/// full scan over the batch of queries that fell back (or all of them,
/// with the index off). Returns per-query ascending hit lists.
fn shard_topk_batch(shard: &Shard, ctx: &ScatterCtx) -> Vec<Vec<Hit>> {
    let q = ctx.queries.len();
    let rows = shard.ids.len();
    let scan_start = Instant::now();
    let mut rerank_us = 0u64;
    let mut results: Vec<Option<Vec<Hit>>> = (0..q).map(|_| None).collect();
    let mut full_scan: Vec<usize> = Vec::new();
    let opts = &ctx.opts;
    match shard.index.as_ref() {
        Some(ix) if rows >= opts.min_rows_for_index => {
            for qi in 0..q {
                let (cands, probes) = ix.candidates(ctx.queries[qi].words());
                if let Some(c) = opts.counters.as_ref() {
                    c.probes.fetch_add(probes as u64, Ordering::Relaxed);
                    c.candidates
                        .fetch_add(cands.len() as u64, Ordering::Relaxed);
                }
                let covers_k = cands.len() >= ctx.k.min(rows);
                let beats_scan = cands.len() * 2 <= rows;
                if covers_k && beats_scan {
                    if let Some(c) = opts.counters.as_ref() {
                        c.indexed_scans.fetch_add(1, Ordering::Relaxed);
                        c.reranked.fetch_add(cands.len() as u64, Ordering::Relaxed);
                    }
                    let rerank_start = Instant::now();
                    results[qi] = Some(rerank_candidates(shard, ctx, qi, &cands));
                    rerank_us += obs::elapsed_us(rerank_start);
                } else {
                    if let Some(c) = opts.counters.as_ref() {
                        c.fallbacks.fetch_add(1, Ordering::Relaxed);
                    }
                    full_scan.push(qi);
                }
            }
        }
        _ => full_scan.extend(0..q),
    }
    if !full_scan.is_empty() {
        let mut heaps: Vec<TopK> = full_scan.iter().map(|_| TopK::new(ctx.k)).collect();
        blocked_full_scan(shard, ctx, &full_scan, &mut heaps);
        for (&qi, heap) in full_scan.iter().zip(heaps) {
            results[qi] = Some(heap.into_sorted_hits());
        }
    }
    // Stage accounting, once per shard job: scan = this shard visit minus
    // its rerank time; rerank recorded only when an indexed rerank ran
    // (so the rerank histogram is not poisoned with zeros from full-scan
    // shards).
    if opts.stages.is_some() || opts.span.is_some() {
        let scan_us = obs::elapsed_us(scan_start).saturating_sub(rerank_us);
        if let Some(st) = opts.stages.as_ref() {
            st.read_scan.record_us(scan_us);
            if rerank_us > 0 {
                st.read_rerank.record_us(rerank_us);
            }
        }
        if let Some(span) = opts.span.as_ref() {
            span.note_scan(scan_us);
            if rerank_us > 0 {
                span.note_rerank(rerank_us);
            }
        }
    }
    results
        .into_iter()
        .map(|r| r.expect("every query routed to exactly one scan path"))
        .collect()
}

/// Merge per-shard partials for one query: ascending by `(dist, id)` under
/// the NaN-total order, deduplicated by id, truncated to `k`.
///
/// The dedup covers a scatter racing a `rebalance`: shard workers take
/// their shard locks independently, so a row moved between shards mid-
/// scatter can be scanned by both workers. Its distance is bitwise
/// identical in both (same words, same cached weight, same query), so the
/// duplicates are adjacent after the sort. (The symmetric race — the row
/// scanned by neither worker — means an in-flight query can transiently
/// miss a mid-move candidate; it is never duplicated or corrupted.)
fn merge(partials: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut merged: Vec<Hit> = partials.into_iter().flatten().collect();
    merged.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.id.cmp(&b.id)));
    merged.dedup_by(|a, b| a.id == b.id);
    merged.truncate(k);
    merged
}

/// Scatter/gather top-k across all shards (persistent executor workers),
/// full-scan only. `k == 0` is a no-op returning no hits — never a panic.
pub fn topk(store: &ShardedStore, query: &BitVec, k: usize) -> Vec<Hit> {
    topk_with(store, query, k, &QueryOpts::full_scan())
}

/// Scatter/gather top-k with explicit routing options (the coordinator's
/// entry point: index on/auto/off comes in through `opts`). The Q = 1
/// case of [`topk_batch_with`] — one code path, no drift.
pub fn topk_with(store: &ShardedStore, query: &BitVec, k: usize, opts: &QueryOpts) -> Vec<Hit> {
    topk_batch_with(store, std::slice::from_ref(query), k, opts)
        .pop()
        .unwrap_or_default()
}

/// Batched scatter/gather: every shard worker answers all queries in one
/// visit over the blocked batch kernels, so the scatter, the arena pass
/// and the per-query `|q̃|` precomputation are paid once per batch instead
/// of once per query. Returns one ascending hit list per query, in query
/// order. Full-scan only; the coordinator uses [`topk_batch_with`].
pub fn topk_batch(store: &ShardedStore, queries: &[BitVec], k: usize) -> Vec<Vec<Hit>> {
    topk_batch_with(store, queries, k, &QueryOpts::full_scan())
}

/// Batched scatter/gather with explicit routing options.
pub fn topk_batch_with(
    store: &ShardedStore,
    queries: &[BitVec],
    k: usize,
    opts: &QueryOpts,
) -> Vec<Vec<Hit>> {
    if k == 0 || queries.is_empty() {
        return queries.iter().map(|_| Vec::new()).collect();
    }
    let ctx = Arc::new(ScatterCtx {
        queries: queries.to_vec(),
        wqs: queries.iter().map(|q| q.count_ones() as f64).collect(),
        k,
        d: store.sketch_dim(),
        opts: opts.clone(),
    });
    // per_shard[s][q] = shard s's top-k for query q
    let mut per_shard: Vec<Vec<Vec<Hit>>> = store.scatter_gather(|_si| {
        let ctx = Arc::clone(&ctx);
        // Queue wait = submit-to-start gap on the shard worker's bounded
        // queue; measured per shard job, first thing the job does.
        let submitted = Instant::now();
        Box::new(move |shard: &Shard| {
            if ctx.opts.stages.is_some() || ctx.opts.span.is_some() {
                let queue_us = obs::elapsed_us(submitted);
                if let Some(st) = ctx.opts.stages.as_ref() {
                    st.read_queue.record_us(queue_us);
                }
                if let Some(span) = ctx.opts.span.as_ref() {
                    span.note_queue(queue_us);
                }
            }
            shard_topk_batch(shard, &ctx)
        })
    });
    let gather_start = Instant::now();
    let merged = (0..queries.len())
        .map(|qi| {
            // move each shard's partial out rather than cloning it
            merge(
                per_shard
                    .iter_mut()
                    .map(|shard| std::mem::take(&mut shard[qi]))
                    .collect(),
                k,
            )
        })
        .collect();
    if opts.stages.is_some() || opts.span.is_some() {
        let gather_us = obs::elapsed_us(gather_start);
        if let Some(st) = opts.stages.as_ref() {
            st.read_gather.record_us(gather_us);
        }
        if let Some(span) = opts.span.as_ref() {
            span.note_gather(gather_us);
        }
    }
    merged
}

/// Estimated distance between two stored points — O(1) id resolution via
/// the store's index, computed on borrowed arena rows.
pub fn distance(store: &ShardedStore, a: usize, b: usize) -> Option<f64> {
    let (wa, wb, ip) = store.pair_stats(a, b)?;
    Some(2.0 * binhamming_from_stats(wa as f64, wb as f64, ip as f64, store.sketch_dim()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn store_with(points: &[BitVec]) -> ShardedStore {
        let store = ShardedStore::new(3, points[0].len());
        for p in points.chunks(4) {
            store.insert_batch(p.to_vec());
        }
        store
    }

    /// The pre-blocking reference: scalar per-query heap scan over every
    /// shard (scoped-spawn scatter). The executor + blocked kernels must
    /// reproduce this bit for bit.
    fn scalar_reference_topk(store: &ShardedStore, query: &BitVec, k: usize) -> Vec<Hit> {
        use crate::sketch::bitvec::and_count_words;
        let d = store.sketch_dim();
        let wq = query.count_ones() as f64;
        let partials = store.par_map_shards(|shard| {
            let mut best = TopK::new(k);
            for row in 0..shard.ids.len() {
                let ip = and_count_words(query.words(), shard.rows.row(row));
                best.offer(shard.ids[row], cham_dist(wq, shard.rows.weight(row), ip, d));
            }
            best.into_sorted_hits()
        });
        merge(partials, k)
    }

    #[test]
    fn topk_finds_the_planted_neighbour() {
        let mut rng = Xoshiro256::new(1);
        let d = 256;
        let mut pts: Vec<BitVec> = (0..40)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        // plant a near-duplicate of the query at id 7
        let query = BitVec::from_indices(d, rng.sample_indices(d, 40));
        let mut near = query.clone();
        near.set(0);
        pts[7] = near;
        let store = store_with(&pts);
        let hits = topk(&store, &query, 5);
        assert_eq!(hits.len(), 5);
        assert_eq!(hits[0].id, 7, "{hits:?}");
        // results sorted ascending
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn blocked_executor_scan_matches_scalar_reference_exactly() {
        let mut rng = Xoshiro256::new(7);
        let d = 130; // ragged tail word in every row
        let pts: Vec<BitVec> = (0..53) // ragged final tile on every shard
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 25)))
            .collect();
        let store = store_with(&pts);
        for k in [1, 3, 25, 100] {
            for q in pts.iter().take(6) {
                assert_eq!(
                    topk(&store, q, k),
                    scalar_reference_topk(&store, q, k),
                    "k={k}"
                );
            }
        }
    }

    #[test]
    fn topk_k_larger_than_corpus() {
        let mut rng = Xoshiro256::new(2);
        let pts: Vec<BitVec> = (0..3)
            .map(|_| BitVec::from_indices(64, rng.sample_indices(64, 10)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 10);
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn topk_k_zero_returns_empty_not_panic() {
        // Regression: the seed kernel indexed hits[k - 1] and underflowed,
        // killing the shard worker and the coordinator with it.
        let mut rng = Xoshiro256::new(6);
        let pts: Vec<BitVec> = (0..10)
            .map(|_| BitVec::from_indices(64, rng.sample_indices(64, 10)))
            .collect();
        let store = store_with(&pts);
        assert!(topk(&store, &pts[0], 0).is_empty());
        let batched = topk_batch(&store, &pts[..3], 0);
        assert_eq!(batched.len(), 3);
        assert!(batched.iter().all(|h| h.is_empty()));
    }

    #[test]
    fn router_never_drops_or_duplicates() {
        let mut rng = Xoshiro256::new(3);
        let pts: Vec<BitVec> = (0..25)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = store_with(&pts);
        let hits = topk(&store, &pts[0], 25);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn batched_queries_match_single_queries() {
        let mut rng = Xoshiro256::new(5);
        let d = 128;
        let pts: Vec<BitVec> = (0..30)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 20)))
            .collect();
        let store = store_with(&pts);
        let queries: Vec<BitVec> = (0..7)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 20)))
            .collect();
        let batched = topk_batch(&store, &queries, 4);
        assert_eq!(batched.len(), queries.len());
        for (q, batch_hits) in queries.iter().zip(&batched) {
            let single = topk(&store, q, 4);
            assert_eq!(&single, batch_hits);
        }
    }

    #[test]
    fn observer_records_read_stages_and_span() {
        let mut rng = Xoshiro256::new(9);
        let d = 128;
        let pts: Vec<BitVec> = (0..24)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 20)))
            .collect();
        let store = store_with(&pts);
        let stages = Arc::new(Stages::new());
        let span = Arc::new(ReadSpan::default());
        let opts =
            QueryOpts::full_scan().with_observer(Arc::clone(&stages), Some(Arc::clone(&span)));
        let plain = topk_batch(&store, &pts[..3], 4);
        let observed = topk_batch_with(&store, &pts[..3], 4, &opts);
        assert_eq!(plain, observed, "observation must not change results");
        // one queue-wait and one scan sample per shard job, one gather per
        // request; rerank never ran (full scan)
        let shards = store.num_shards() as u64;
        assert_eq!(stages.read_queue.count(), shards);
        assert_eq!(stages.read_scan.count(), shards);
        assert_eq!(stages.read_rerank.count(), 0);
        assert_eq!(stages.read_gather.count(), 1);
        // the span kept the worst per-stage time for the slow-op record
        assert!(span.ms(&span.scan_us) >= 0.0);
        assert_eq!(span.ms(&span.rerank_us), 0.0);
    }

    #[test]
    fn distance_self_is_zero() {
        let mut rng = Xoshiro256::new(4);
        let pts: Vec<BitVec> = (0..4)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 25)))
            .collect();
        let store = store_with(&pts);
        assert_eq!(distance(&store, 0, 0), Some(0.0));
        assert!(distance(&store, 0, 99).is_none());
        let d01 = distance(&store, 0, 1).unwrap();
        let d10 = distance(&store, 1, 0).unwrap();
        assert!((d01 - d10).abs() < 1e-9);
    }

    fn indexed_store_with(points: &[BitVec]) -> ShardedStore {
        let cfg = crate::index::IndexConfig {
            mode: crate::index::IndexMode::On,
            ..Default::default()
        };
        let store = ShardedStore::with_index(3, points[0].len(), &cfg, 17);
        for p in points.chunks(4) {
            store.insert_batch(p.to_vec());
        }
        store
    }

    #[test]
    fn indexed_topk_finds_the_planted_neighbour() {
        let mut rng = Xoshiro256::new(31);
        let d = 256;
        let mut pts: Vec<BitVec> = (0..60)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        let query = BitVec::from_indices(d, rng.sample_indices(d, 40));
        let mut near = query.clone();
        near.set(0);
        pts[13] = near;
        let store = indexed_store_with(&pts);
        let hits = topk_with(&store, &query, 5, &QueryOpts::indexed(0, None));
        assert_eq!(hits.len(), 5, "fallback must guarantee k hits");
        assert_eq!(hits[0].id, 13, "{hits:?}");
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn indexed_fallback_guarantees_full_result_count() {
        // k larger than any plausible candidate set: every shard must fall
        // back and the indexed path must return exactly min(k, n) hits.
        let mut rng = Xoshiro256::new(32);
        let pts: Vec<BitVec> = (0..25)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = indexed_store_with(&pts);
        let counters = Arc::new(IndexCounters::default());
        let opts = QueryOpts::indexed(0, Some(counters.clone()));
        let hits = topk_with(&store, &pts[0], 25, &opts);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..25).collect::<Vec<_>>());
        assert!(counters.probes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn indexed_batch_matches_indexed_single() {
        let mut rng = Xoshiro256::new(33);
        let d = 256;
        let pts: Vec<BitVec> = (0..40)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 40)))
            .collect();
        let store = indexed_store_with(&pts);
        let opts = QueryOpts::indexed(0, None);
        let queries: Vec<BitVec> = pts[..6].to_vec();
        let batched = topk_batch_with(&store, &queries, 4, &opts);
        for (q, batch_hits) in queries.iter().zip(&batched) {
            assert_eq!(&topk_with(&store, q, 4, &opts), batch_hits);
        }
    }

    #[test]
    fn min_rows_threshold_gates_the_index_path() {
        let mut rng = Xoshiro256::new(34);
        let pts: Vec<BitVec> = (0..30)
            .map(|_| BitVec::from_indices(128, rng.sample_indices(128, 20)))
            .collect();
        let store = indexed_store_with(&pts);
        // threshold above every shard size → pure full scan, no counters
        let counters = Arc::new(IndexCounters::default());
        let opts = QueryOpts::indexed(1_000_000, Some(counters.clone()));
        let gated = topk_with(&store, &pts[0], 5, &opts);
        assert_eq!(gated, topk(&store, &pts[0], 5));
        assert_eq!(counters.probes.load(Ordering::Relaxed), 0);
        assert_eq!(counters.fallbacks.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn counters_account_every_indexed_shard_scan() {
        let mut rng = Xoshiro256::new(35);
        let pts: Vec<BitVec> = (0..45)
            .map(|_| BitVec::from_indices(256, rng.sample_indices(256, 40)))
            .collect();
        let store = indexed_store_with(&pts);
        let counters = Arc::new(IndexCounters::default());
        let opts = QueryOpts::indexed(0, Some(counters.clone()));
        let _ = topk_with(&store, &pts[7], 3, &opts);
        let scans = counters.indexed_scans.load(Ordering::Relaxed)
            + counters.fallbacks.load(Ordering::Relaxed);
        assert_eq!(scans, store.num_shards() as u64);
        assert!(counters.probes.load(Ordering::Relaxed) >= scans);
    }

    #[test]
    fn merge_dedups_a_row_seen_by_two_shards() {
        // mid-rebalance a moved row can be scanned by both its old and new
        // shard; both see identical (id, dist) and the merge must keep one
        let partials = vec![
            vec![Hit { id: 4, dist: 1.5 }, Hit { id: 0, dist: 2.0 }],
            vec![Hit { id: 4, dist: 1.5 }, Hit { id: 9, dist: 3.0 }],
        ];
        let merged = merge(partials, 3);
        let ids: Vec<usize> = merged.iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![4, 0, 9]);
    }

    #[test]
    fn merge_is_nan_safe() {
        // Adversarial partials containing NaN distances must merge without
        // panicking, with NaN ordered after every finite hit.
        let partials = vec![
            vec![
                Hit { id: 0, dist: 2.0 },
                Hit {
                    id: 1,
                    dist: f64::NAN,
                },
            ],
            vec![Hit { id: 2, dist: 1.0 }],
        ];
        let merged = merge(partials, 3);
        assert_eq!(merged[0].id, 2);
        assert_eq!(merged[1].id, 0);
        assert!(merged[2].dist.is_nan());
    }
}
