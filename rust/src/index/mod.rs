//! Sublinear candidate generation: a banded, multi-probe bit-sampling
//! Hamming-LSH index over [`crate::sketch::SketchMatrix`] rows.
//!
//! The paper's `hlsh` baseline (Section 5; Gionis–Indyk–Motwani bit
//! sampling) is an *estimator* — sample coordinates, scale the restricted
//! Hamming distance. The same primitive composes with Cabin sketches as a
//! *search index*: because sketches are binary and Cham is monotone-ish in
//! sketch Hamming distance, rows whose sampled sketch bits agree with the
//! query's are exactly the rows likely to be close, and the sparse-binary
//! analyses of arXiv:1910.04658 / arXiv:1612.06057 say a handful of sampled
//! bits already carry most of the pairwise signal.
//!
//! Layout:
//!
//! ```text
//!   band 0: b sampled bit positions ── key(ũ) ∈ {0,1}^b ──► bucket table
//!   band 1: independent sample      ── …                 ──► bucket table
//!   …        (L bands total; a row lands in one bucket per band)
//! ```
//!
//! Querying looks up the query's key in every band, plus `probes`
//! *multi-probe* buckets per band obtained by flipping the query key's
//! lowest-confidence sampled bits — the bits whose empirical set-frequency
//! over the indexed rows is closest to 1/2, i.e. the bits most likely to
//! differ in a true near neighbour. The union of inspected buckets is the
//! candidate set; the caller reranks candidates with the exact Cham
//! estimate (see `coordinator::router`) and falls back to a full scan when
//! the candidate set is too small to guarantee `k` hits or too large to
//! beat the scan.
//!
//! Maintenance contract: the index lives next to its arena inside a shard
//! (same lock) and is maintained incrementally — inserts append, and
//! rebalance moves (which always pop an arena's trailing row) are mirrored
//! with a trailing-row removal plus an append, O(L) each. Bulk
//! reconstruction (`LshIndex::rebuild`) exists for recovery paths; the
//! serving paths never need it (see `coordinator::store`).
//!
//! Submodules: [`config`] (tuning knobs + wire-stats view), [`sample`]
//! (the sorted-coordinate-sample helper shared with the `hlsh` baseline),
//! [`lsh`] (the index proper).

pub mod config;
pub mod lsh;
pub mod sample;

pub use config::{IndexConfig, IndexMode};
pub use lsh::LshIndex;
pub use sample::SortedSample;
