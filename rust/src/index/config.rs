//! Tuning knobs for the Hamming-LSH index, exposed through
//! `CoordinatorConfig` and (read-only) through the wire protocol's `stats`
//! response.

/// Whether the coordinator routes queries through the shard indexes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexMode {
    /// Maintain the index, but use it only for shards holding at least
    /// [`IndexConfig::auto_min_rows`] rows — below that a full arena scan
    /// is both exact and already fast.
    Auto,
    /// Use the index for every shard, regardless of size.
    On,
    /// No index: every query is a full heap scan (the pre-index behaviour).
    Off,
}

/// Banded bit-sampling LSH parameters.
///
/// Recall intuition: a neighbour differing in `r` of the `d` sketch bits
/// collides with the query in one band with probability `≈ (1 - r/d)^b`,
/// and is generated as a candidate unless all `L` bands miss —
/// `1 - (1 - (1-r/d)^b)^L`, further boosted by multi-probing. The defaults
/// (`L = 8`, `b = 16`, `probes = 2`) put recall@10 above 0.99 for planted
/// neighbours within ~4% sketch-bit noise at `d = 256` (see
/// `tests/prop_index.rs`), while inspecting only `L·(1+probes)` buckets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IndexConfig {
    /// `L` — number of independent bands (bucket tables).
    pub bands: usize,
    /// `b` — sampled sketch-bit positions per band (clamped to 64: band
    /// keys are packed into a `u64`).
    pub band_bits: usize,
    /// Extra multi-probe buckets per band: single-bit flips of the query
    /// key, lowest-confidence bits first. `0` disables multi-probing.
    pub probes: usize,
    /// Routing policy (auto / on / off).
    pub mode: IndexMode,
    /// `Auto` threshold: a shard must hold at least this many rows before
    /// its queries go through the index.
    pub auto_min_rows: usize,
}

impl Default for IndexConfig {
    fn default() -> Self {
        Self {
            bands: 8,
            band_bits: 16,
            probes: 2,
            mode: IndexMode::Auto,
            auto_min_rows: 1024,
        }
    }
}

impl IndexConfig {
    /// Whether shard indexes should be built at all.
    pub fn enabled(&self) -> bool {
        self.mode != IndexMode::Off
    }

    /// Clamp to representable values for a `sketch_bits`-bit arena: at
    /// least one band, and `1 ≤ band_bits ≤ min(64, sketch_bits)` so a
    /// band key always fits a `u64` and never oversamples the sketch.
    pub fn normalized(mut self, sketch_bits: usize) -> Self {
        self.bands = self.bands.max(1);
        self.band_bits = self.band_bits.clamp(1, 64.min(sketch_bits.max(1)));
        self
    }

    /// The router's per-shard activation threshold for this mode:
    /// `0` (always) for `On`, `auto_min_rows` for `Auto`, and `usize::MAX`
    /// (never) for `Off`.
    pub fn min_rows_for_index(&self) -> usize {
        match self.mode {
            IndexMode::On => 0,
            IndexMode::Auto => self.auto_min_rows,
            IndexMode::Off => usize::MAX,
        }
    }

    /// Parse a CLI/wire mode string (`auto` | `on` | `off`).
    pub fn mode_from_str(s: &str) -> Option<IndexMode> {
        match s {
            "auto" => Some(IndexMode::Auto),
            "on" => Some(IndexMode::On),
            "off" => Some(IndexMode::Off),
            _ => None,
        }
    }

    /// CLI-friendly variant: anything unrecognised warns on stderr (with
    /// `context` as the log prefix) and falls back to `Auto`, so the
    /// server binary and the examples cannot drift in `--index` handling.
    pub fn mode_from_str_or_warn(s: &str, context: &str) -> IndexMode {
        Self::mode_from_str(s).unwrap_or_else(|| {
            crate::obs::log::warn(
                context,
                "unknown_index_mode",
                &[
                    ("value", crate::obs::log::V::s(s)),
                    ("want", crate::obs::log::V::s("auto|on|off")),
                    ("using", crate::obs::log::V::s("auto")),
                ],
            );
            IndexMode::Auto
        })
    }

    /// Read-only configuration view merged into the `stats` response
    /// (`index_cfg_*` so the names can never collide with the
    /// `index_*` traffic counters in `coordinator::Metrics`).
    pub fn stats_fields(&self) -> Vec<(String, f64)> {
        let mode = match self.mode {
            IndexMode::Off => 0.0,
            IndexMode::Auto => 1.0,
            IndexMode::On => 2.0,
        };
        vec![
            ("index_cfg_mode".into(), mode),
            ("index_cfg_bands".into(), self.bands as f64),
            ("index_cfg_band_bits".into(), self.band_bits as f64),
            ("index_cfg_probes".into(), self.probes as f64),
            ("index_cfg_auto_min_rows".into(), self.auto_min_rows as f64),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_clamps_band_bits() {
        let cfg = IndexConfig {
            bands: 0,
            band_bits: 200,
            ..Default::default()
        };
        let n = cfg.normalized(1024);
        assert_eq!(n.bands, 1);
        assert_eq!(n.band_bits, 64);
        // tiny sketches clamp harder
        assert_eq!(cfg.normalized(8).band_bits, 8);
        assert_eq!(cfg.normalized(0).band_bits, 1);
    }

    #[test]
    fn min_rows_tracks_mode() {
        let with_mode = |mode| IndexConfig {
            mode,
            ..Default::default()
        };
        assert_eq!(with_mode(IndexMode::On).min_rows_for_index(), 0);
        let auto = with_mode(IndexMode::Auto);
        assert_eq!(auto.min_rows_for_index(), auto.auto_min_rows);
        assert_eq!(with_mode(IndexMode::Off).min_rows_for_index(), usize::MAX);
        assert!(!with_mode(IndexMode::Off).enabled());
        assert!(auto.enabled());
    }

    #[test]
    fn mode_strings_roundtrip() {
        assert_eq!(IndexConfig::mode_from_str("auto"), Some(IndexMode::Auto));
        assert_eq!(IndexConfig::mode_from_str("on"), Some(IndexMode::On));
        assert_eq!(IndexConfig::mode_from_str("off"), Some(IndexMode::Off));
        assert_eq!(IndexConfig::mode_from_str("sideways"), None);
        // the warn variant parses identically and degrades to Auto
        assert_eq!(IndexConfig::mode_from_str_or_warn("off", "test"), IndexMode::Off);
        assert_eq!(
            IndexConfig::mode_from_str_or_warn("sideways", "test"),
            IndexMode::Auto
        );
    }

    #[test]
    fn stats_fields_use_cfg_prefix() {
        let fields = IndexConfig::default().stats_fields();
        assert!(fields.iter().all(|(n, _)| n.starts_with("index_cfg_")));
        assert!(fields.iter().any(|(n, v)| n == "index_cfg_bands" && *v == 8.0));
    }
}
