//! The banded multi-probe bit-sampling LSH index over packed sketch rows.
//!
//! One [`LshIndex`] serves one [`SketchMatrix`] arena (a coordinator
//! shard): `L` bands, each holding an independent [`SortedSample`] of `b`
//! sketch-bit positions and a `key → rows` bucket table. Row identifiers
//! are *positional* (the arena row number), which keeps insertion O(L);
//! rebalance moves always pop an arena's trailing row, so the index
//! follows them with [`LshIndex::remove_last`] + [`LshIndex::insert`] —
//! O(L) per move — and [`LshIndex::rebuild`] remains as a bulk fallback
//! (see `coordinator::store`).
//!
//! Multi-probe: each band also maintains per-sampled-bit set counts over
//! the indexed rows. At query time the `probes` extra buckets per band are
//! the single-bit flips of the query key at the *lowest-confidence* bits —
//! the sampled positions whose empirical set-frequency is closest to 1/2.
//! Those bits split the corpus most evenly, so they are precisely the bits
//! a true near neighbour is most likely to land on the other side of;
//! flipping them first buys the most recall per extra bucket probe
//! (the standard multi-probe LSH argument, specialised to binary keys).

use super::config::IndexConfig;
use super::sample::SortedSample;
use crate::sketch::SketchMatrix;
use crate::util::rng::{mix64, Xoshiro256};
use std::collections::HashMap;

/// One band: an independent bit sample, its bucket table, and the per-bit
/// set counts that drive multi-probe ordering.
#[derive(Debug)]
struct Band {
    sample: SortedSample,
    /// `ones[j]` = number of indexed rows whose sampled bit `j` is set.
    ones: Vec<u32>,
    /// Band key → arena row numbers (insertion order within a bucket).
    table: HashMap<u64, Vec<u32>>,
}

impl Band {
    fn clear(&mut self) {
        self.table.clear();
        for c in self.ones.iter_mut() {
            *c = 0;
        }
    }
}

/// Banded multi-probe Hamming-LSH index over one sketch arena.
#[derive(Debug)]
pub struct LshIndex {
    bands: Vec<Band>,
    probes: usize,
    rows: usize,
}

impl LshIndex {
    /// Build an empty index for `sketch_bits`-bit rows. The band samples
    /// are derived deterministically from `seed`, so every shard of a
    /// store (and a rebuilt index) samples the same positions.
    pub fn new(cfg: &IndexConfig, sketch_bits: usize, seed: u64) -> Self {
        let cfg = cfg.normalized(sketch_bits);
        let bands = (0..cfg.bands)
            .map(|i| {
                let mut rng = Xoshiro256::new(mix64(seed ^ 0xB175_A3C0 ^ ((i as u64) << 20)));
                let sample = SortedSample::draw(&mut rng, sketch_bits.max(1), cfg.band_bits);
                Band {
                    ones: vec![0; sample.len()],
                    table: HashMap::new(),
                    sample,
                }
            })
            .collect();
        Self {
            bands,
            probes: cfg.probes,
            rows: 0,
        }
    }

    /// Number of indexed rows.
    #[inline]
    pub fn len(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Number of bands (`L`).
    pub fn num_bands(&self) -> usize {
        self.bands.len()
    }

    /// Index the packed words of arena row `row`. Rows must be inserted in
    /// arena order (`row == len()`), mirroring `SketchMatrix::push`.
    pub fn insert(&mut self, row: usize, words: &[u64]) {
        debug_assert_eq!(row, self.rows, "index rows must mirror arena order");
        for band in &mut self.bands {
            let key = band.sample.key_of_words(words);
            let mut set = key;
            while set != 0 {
                band.ones[set.trailing_zeros() as usize] += 1;
                set &= set - 1;
            }
            band.table.entry(key).or_default().push(row as u32);
        }
        self.rows += 1;
    }

    /// Un-index the most recently indexed row (`len() - 1`), given its
    /// packed words — the exact inverse of [`LshIndex::insert`]. Rebalance
    /// moves pop an arena's *trailing* row, so trailing removal is the
    /// only removal shape the store ever needs, and it keeps a move
    /// O(L) instead of an O(rows · L) rebuild.
    pub fn remove_last(&mut self, words: &[u64]) {
        debug_assert!(self.rows > 0, "remove_last on an empty index");
        let row = (self.rows - 1) as u32;
        for band in &mut self.bands {
            let key = band.sample.key_of_words(words);
            let mut set = key;
            while set != 0 {
                band.ones[set.trailing_zeros() as usize] -= 1;
                set &= set - 1;
            }
            if let Some(bucket) = band.table.get_mut(&key) {
                if let Some(pos) = bucket.iter().rposition(|&r| r == row) {
                    bucket.swap_remove(pos);
                }
                if bucket.is_empty() {
                    band.table.remove(&key);
                }
            }
        }
        self.rows -= 1;
    }

    /// Un-index the row at `pos` of a swap-remove: the row's own entries
    /// are dropped and, unless `pos` was the trailing row, the former
    /// trailing row (whose packed words are `last_words`) is re-keyed
    /// from row number `len() - 1` to `pos` — the index-side mirror of
    /// [`crate::sketch::SketchMatrix::swap_remove_row`]. O(L) per delete.
    pub fn remove_at(&mut self, pos: usize, removed_words: &[u64], last_words: &[u64]) {
        debug_assert!(pos < self.rows, "remove_at past the indexed rows");
        let last = self.rows - 1;
        if pos == last {
            self.remove_last(removed_words);
            return;
        }
        for band in &mut self.bands {
            // drop the removed row's entry and its bit counts
            let removed_key = band.sample.key_of_words(removed_words);
            let mut set = removed_key;
            while set != 0 {
                band.ones[set.trailing_zeros() as usize] -= 1;
                set &= set - 1;
            }
            if let Some(bucket) = band.table.get_mut(&removed_key) {
                if let Some(i) = bucket.iter().rposition(|&r| r == pos as u32) {
                    bucket.swap_remove(i);
                }
                if bucket.is_empty() {
                    band.table.remove(&removed_key);
                }
            }
            // the trailing row moved into `pos`: same key, new row number
            let last_key = band.sample.key_of_words(last_words);
            if let Some(bucket) = band.table.get_mut(&last_key) {
                if let Some(i) = bucket.iter().rposition(|&r| r == last as u32) {
                    bucket[i] = pos as u32;
                }
            }
        }
        self.rows -= 1;
    }

    /// Re-key row `pos` from `old_words` to `new_words` in place — the
    /// index-side mirror of [`crate::sketch::SketchMatrix::overwrite_row`]
    /// (upsert). O(L) per update.
    pub fn update_row(&mut self, pos: usize, old_words: &[u64], new_words: &[u64]) {
        debug_assert!(pos < self.rows, "update_row past the indexed rows");
        for band in &mut self.bands {
            let old_key = band.sample.key_of_words(old_words);
            let new_key = band.sample.key_of_words(new_words);
            let mut cleared = old_key;
            while cleared != 0 {
                band.ones[cleared.trailing_zeros() as usize] -= 1;
                cleared &= cleared - 1;
            }
            let mut set = new_key;
            while set != 0 {
                band.ones[set.trailing_zeros() as usize] += 1;
                set &= set - 1;
            }
            if old_key == new_key {
                continue; // bucket membership unchanged
            }
            if let Some(bucket) = band.table.get_mut(&old_key) {
                if let Some(i) = bucket.iter().rposition(|&r| r == pos as u32) {
                    bucket.swap_remove(i);
                }
                if bucket.is_empty() {
                    band.table.remove(&old_key);
                }
            }
            band.table.entry(new_key).or_default().push(pos as u32);
        }
    }

    /// Drop every bucket and re-index the arena from scratch (bulk
    /// reconstruction). The band samples are retained, so a rebuilt index
    /// answers queries identically to one grown incrementally over the
    /// same rows.
    pub fn rebuild(&mut self, matrix: &SketchMatrix) {
        for band in &mut self.bands {
            band.clear();
        }
        self.rows = 0;
        for (row, words) in matrix.rows().enumerate() {
            self.insert(row, words);
        }
    }

    /// Candidate arena rows for a query's packed words: the union of the
    /// exact bucket per band plus up to `probes` lowest-confidence
    /// single-bit-flip buckets per band. Returns the sorted, deduplicated
    /// candidate rows and the number of bucket probes issued.
    pub fn candidates(&self, query_words: &[u64]) -> (Vec<u32>, usize) {
        let mut out: Vec<u32> = Vec::new();
        let mut probes_issued = 0usize;
        let total = self.rows as f64;
        for band in &self.bands {
            let key = band.sample.key_of_words(query_words);
            probes_issued += 1;
            if let Some(bucket) = band.table.get(&key) {
                out.extend_from_slice(bucket);
            }
            if self.probes == 0 || band.sample.is_empty() {
                continue;
            }
            // flip order: ascending margin |p̂ - 1/2| of each sampled bit's
            // empirical set-frequency — least-informative bits first, ties
            // by ascending bit rank. `probes` is small, so repeated linear
            // minimum scans over ≤ 64 counters beat sorting (and allocate
            // nothing on the query hot path); `chosen` marks picked bits.
            let take = self.probes.min(band.sample.len());
            let mut chosen: u64 = 0;
            for _ in 0..take {
                let mut best_j = 0usize;
                let mut best_margin = f64::INFINITY;
                for (j, &c) in band.ones.iter().enumerate() {
                    if (chosen >> j) & 1 == 1 {
                        continue;
                    }
                    let p = if self.rows == 0 { 0.0 } else { c as f64 / total };
                    let margin = (p - 0.5).abs();
                    if margin < best_margin {
                        best_margin = margin;
                        best_j = j;
                    }
                }
                chosen |= 1u64 << best_j;
                probes_issued += 1;
                if let Some(bucket) = band.table.get(&(key ^ (1u64 << best_j))) {
                    out.extend_from_slice(bucket);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        (out, probes_issued)
    }

    /// Rough memory footprint in bytes (buckets + counters + samples).
    pub fn memory_bytes(&self) -> usize {
        self.bands
            .iter()
            .map(|b| {
                b.table
                    .values()
                    .map(|v| 8 + v.len() * 4)
                    .sum::<usize>()
                    + b.ones.len() * 4
                    + b.sample.len() * 8
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::config::IndexMode;
    use crate::sketch::BitVec;

    const DIM: usize = 256;

    fn cfg() -> IndexConfig {
        IndexConfig {
            mode: IndexMode::On,
            ..Default::default()
        }
    }

    fn random_rows(seed: u64, n: usize, ones: usize) -> Vec<BitVec> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, ones)))
            .collect()
    }

    fn flip_bits(v: &BitVec, flips: &[usize]) -> BitVec {
        let mut out = v.clone();
        for &i in flips {
            if out.get(i) {
                out.clear(i);
            } else {
                out.set(i);
            }
        }
        out
    }

    #[test]
    fn exact_duplicates_always_collide() {
        let rows = random_rows(1, 60, 40);
        let mut ix = LshIndex::new(&cfg(), DIM, 9);
        for (i, r) in rows.iter().enumerate() {
            ix.insert(i, r.words());
        }
        assert_eq!(ix.len(), 60);
        for (i, r) in rows.iter().enumerate() {
            let (cands, probes) = ix.candidates(r.words());
            assert!(
                cands.binary_search(&(i as u32)).is_ok(),
                "row {i} missing from its own candidates"
            );
            // exact probe per band plus `probes` flips per band
            assert_eq!(probes, ix.num_bands() * (1 + cfg().probes));
        }
    }

    #[test]
    fn near_neighbour_is_generated_as_candidate() {
        // 2 flipped bits of 256: per-band collision ≈ (1 - 16/256)^2 ≈ 0.88,
        // all-8-bands miss ≈ 5e-8 — deterministic seeds make this stable.
        let rows = random_rows(2, 400, 40);
        let mut ix = LshIndex::new(&cfg(), DIM, 5);
        for (i, r) in rows.iter().enumerate() {
            ix.insert(i, r.words());
        }
        let query = flip_bits(&rows[123], &[1, 130]);
        let (cands, _) = ix.candidates(query.words());
        assert!(
            cands.binary_search(&123).is_ok(),
            "near neighbour not generated ({} candidates)",
            cands.len()
        );
    }

    #[test]
    fn rebuild_matches_incremental_build() {
        let rows = random_rows(3, 120, 30);
        let matrix = SketchMatrix::from_sketches(&rows);
        let mut incremental = LshIndex::new(&cfg(), DIM, 7);
        for (i, r) in rows.iter().enumerate() {
            incremental.insert(i, r.words());
        }
        let mut rebuilt = LshIndex::new(&cfg(), DIM, 7);
        rebuilt.rebuild(&matrix);
        assert_eq!(rebuilt.len(), incremental.len());
        for q in random_rows(4, 10, 30) {
            assert_eq!(
                incremental.candidates(q.words()),
                rebuilt.candidates(q.words())
            );
        }
        // rebuilding twice is idempotent
        rebuilt.rebuild(&matrix);
        assert_eq!(rebuilt.len(), rows.len());
    }

    #[test]
    fn remove_last_is_the_exact_inverse_of_insert() {
        let rows = random_rows(9, 40, 30);
        let mut full = LshIndex::new(&cfg(), DIM, 3);
        for (i, r) in rows.iter().enumerate() {
            full.insert(i, r.words());
        }
        // un-index the trailing 15 rows in reverse insertion order
        for r in rows[25..].iter().rev() {
            full.remove_last(r.words());
        }
        assert_eq!(full.len(), 25);
        let mut prefix = LshIndex::new(&cfg(), DIM, 3);
        for (i, r) in rows[..25].iter().enumerate() {
            prefix.insert(i, r.words());
        }
        // identical candidates AND probe counts (the multi-probe order is
        // driven by the per-bit counters, which must roll back exactly)
        for q in random_rows(10, 6, 30) {
            assert_eq!(full.candidates(q.words()), prefix.candidates(q.words()));
        }
        // drain to empty and regrow — still consistent
        for r in rows[..25].iter().rev() {
            full.remove_last(r.words());
        }
        assert!(full.is_empty());
        full.insert(0, rows[3].words());
        assert_eq!(full.candidates(rows[3].words()).0, vec![0]);
    }

    #[test]
    fn remove_at_matches_a_rebuild_over_the_swapped_arena() {
        let rows = random_rows(11, 50, 30);
        let mut matrix = SketchMatrix::from_sketches(&rows);
        let mut ix = LshIndex::new(&cfg(), DIM, 17);
        ix.rebuild(&matrix);
        let mut rng = Xoshiro256::new(12);
        // random interior/head/tail deletes, mirrored into the arena
        while matrix.len() > 5 {
            let pos = rng.gen_range(matrix.len() as u64) as usize;
            let removed: Vec<u64> = matrix.row(pos).to_vec();
            let last: Vec<u64> = matrix.row(matrix.len() - 1).to_vec();
            ix.remove_at(pos, &removed, &last);
            matrix.swap_remove_row(pos);
            assert_eq!(ix.len(), matrix.len());
        }
        let mut rebuilt = LshIndex::new(&cfg(), DIM, 17);
        rebuilt.rebuild(&matrix);
        for q in random_rows(13, 8, 30) {
            assert_eq!(ix.candidates(q.words()), rebuilt.candidates(q.words()));
        }
    }

    #[test]
    fn update_row_matches_a_rebuild_over_the_overwritten_arena() {
        let rows = random_rows(14, 40, 30);
        let mut matrix = SketchMatrix::from_sketches(&rows);
        let mut ix = LshIndex::new(&cfg(), DIM, 19);
        ix.rebuild(&matrix);
        let mut rng = Xoshiro256::new(15);
        let fresh = random_rows(16, 12, 35);
        for f in &fresh {
            let pos = rng.gen_range(matrix.len() as u64) as usize;
            let old: Vec<u64> = matrix.row(pos).to_vec();
            ix.update_row(pos, &old, f.words());
            matrix.overwrite_row(pos, f.words(), f.count_ones() as u32);
        }
        // self-update is a no-op in effect
        let same: Vec<u64> = matrix.row(0).to_vec();
        ix.update_row(0, &same, &same);
        let mut rebuilt = LshIndex::new(&cfg(), DIM, 19);
        rebuilt.rebuild(&matrix);
        assert_eq!(ix.len(), rebuilt.len());
        for q in random_rows(18, 8, 30) {
            assert_eq!(ix.candidates(q.words()), rebuilt.candidates(q.words()));
        }
    }

    #[test]
    fn more_probes_generate_a_superset() {
        let rows = random_rows(5, 300, 40);
        let base = IndexConfig {
            probes: 0,
            ..cfg()
        };
        let probed = IndexConfig {
            probes: 4,
            ..cfg()
        };
        let mut a = LshIndex::new(&base, DIM, 13);
        let mut b = LshIndex::new(&probed, DIM, 13);
        for (i, r) in rows.iter().enumerate() {
            a.insert(i, r.words());
            b.insert(i, r.words());
        }
        for q in random_rows(6, 8, 40) {
            let (small, p0) = a.candidates(q.words());
            let (large, p4) = b.candidates(q.words());
            assert!(p4 > p0);
            for c in &small {
                assert!(large.binary_search(c).is_ok(), "probing lost candidate {c}");
            }
        }
    }

    #[test]
    fn empty_index_yields_no_candidates() {
        let ix = LshIndex::new(&cfg(), DIM, 1);
        let q = random_rows(7, 1, 40).pop().unwrap();
        let (cands, probes) = ix.candidates(q.words());
        assert!(cands.is_empty());
        assert!(probes >= ix.num_bands());
        assert!(ix.is_empty());
        // 8 bands × 16 sampled bits × (4-byte counter + 8-byte position)
        assert_eq!(ix.memory_bytes(), 8 * 16 * (4 + 8));
    }

    #[test]
    fn oversized_band_bits_are_clamped_not_fatal() {
        let wide = IndexConfig {
            band_bits: 500,
            bands: 2,
            ..cfg()
        };
        let mut ix = LshIndex::new(&wide, 96, 3);
        let mut rng = Xoshiro256::new(8);
        let v = BitVec::from_indices(96, rng.sample_indices(96, 20));
        ix.insert(0, v.words());
        let (cands, _) = ix.candidates(v.words());
        assert_eq!(cands, vec![0]);
    }
}
