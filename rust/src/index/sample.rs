//! Sorted coordinate samples — the bit-sampling primitive shared by the
//! `hlsh` estimator baseline ([`crate::baselines::hamming_lsh`]) and the
//! LSH index bands ([`super::lsh`]).
//!
//! Both users draw `k` distinct positions from a universe of `n`
//! coordinates and keep them sorted: the baseline walks a vector's sorted
//! nonzeros against the sample with binary search ([`SortedSample::rank_of`]),
//! the index gathers the sampled bits of a packed sketch row into a bucket
//! key ([`SortedSample::key_of_words`]). Keeping one implementation stops
//! the sampling/walk logic drifting between the two.

use crate::util::rng::Xoshiro256;

/// `k` distinct coordinate positions in ascending order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SortedSample {
    positions: Vec<usize>,
}

impl SortedSample {
    /// Draw `k` distinct positions uniformly from `[0, universe)` (clamped
    /// to the universe size) and sort them.
    pub fn draw(rng: &mut Xoshiro256, universe: usize, k: usize) -> Self {
        let mut positions = rng.sample_indices(universe, k.min(universe));
        positions.sort_unstable();
        Self { positions }
    }

    /// Wrap explicit positions (sorted and deduplicated here).
    pub fn from_positions(mut positions: Vec<usize>) -> Self {
        positions.sort_unstable();
        positions.dedup();
        Self { positions }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.positions.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    /// The sampled positions, ascending.
    #[inline]
    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    /// Rank of `position` within the sample, if sampled — the sorted-sample
    /// walk: callers iterate their sparse nonzeros and binary-search each
    /// one here instead of materialising a dense membership table.
    #[inline]
    pub fn rank_of(&self, position: usize) -> Option<usize> {
        self.positions.binary_search(&position).ok()
    }

    /// Gather the sampled bits of a packed bit row (`u64` words, LSB
    /// first — the [`crate::sketch::BitVec`] / [`crate::sketch::SketchMatrix`]
    /// layout) into a key: sample rank `j` becomes key bit `j`. Requires
    /// `len() <= 64`.
    #[inline]
    pub fn key_of_words(&self, words: &[u64]) -> u64 {
        debug_assert!(self.positions.len() <= 64, "band key must fit a u64");
        let mut key = 0u64;
        for (j, &pos) in self.positions.iter().enumerate() {
            key |= ((words[pos >> 6] >> (pos & 63)) & 1) << j;
        }
        key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::BitVec;

    #[test]
    fn draw_is_sorted_distinct_and_clamped() {
        let mut rng = Xoshiro256::new(3);
        let s = SortedSample::draw(&mut rng, 100, 20);
        assert_eq!(s.len(), 20);
        for w in s.positions().windows(2) {
            assert!(w[0] < w[1], "{:?}", s.positions());
        }
        // k > universe clamps instead of panicking
        let t = SortedSample::draw(&mut rng, 5, 64);
        assert_eq!(t.len(), 5);
        assert_eq!(t.positions(), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn rank_of_matches_membership() {
        let s = SortedSample::from_positions(vec![9, 2, 40, 2, 17]);
        assert_eq!(s.positions(), &[2, 9, 17, 40]);
        assert_eq!(s.rank_of(2), Some(0));
        assert_eq!(s.rank_of(17), Some(2));
        assert_eq!(s.rank_of(40), Some(3));
        assert_eq!(s.rank_of(3), None);
        assert_eq!(s.rank_of(41), None);
    }

    #[test]
    fn key_of_words_matches_bit_reads() {
        let mut rng = Xoshiro256::new(7);
        let bits = 200;
        let v = BitVec::from_indices(bits, rng.sample_indices(bits, 60));
        let s = SortedSample::draw(&mut rng, bits, 24);
        let key = s.key_of_words(v.words());
        for (j, &pos) in s.positions().iter().enumerate() {
            assert_eq!((key >> j) & 1 == 1, v.get(pos), "rank {j} pos {pos}");
        }
        // unsampled high key bits stay zero
        assert_eq!(key >> s.len(), 0);
    }

    #[test]
    fn identical_rows_share_keys_differing_rows_usually_do_not() {
        let mut rng = Xoshiro256::new(11);
        let bits = 256;
        let a = BitVec::from_indices(bits, rng.sample_indices(bits, 64));
        let b = BitVec::from_indices(bits, rng.sample_indices(bits, 64));
        let s = SortedSample::draw(&mut rng, bits, 32);
        assert_eq!(s.key_of_words(a.words()), s.key_of_words(a.words()));
        // two random 64/256 rows disagree on ~32 sampled bits of 32
        // positions with overwhelming probability
        assert_ne!(s.key_of_words(a.words()), s.key_of_words(b.words()));
    }
}
