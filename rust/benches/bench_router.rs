//! Router serving-path benchmarks: the two tentpole optimisations, each
//! measured against the path it replaced.
//!
//! * **Scatter**: the persistent shard-executor (long-lived worker per
//!   shard, bounded queues) vs the old per-request scoped-spawn scatter
//!   (`ShardedStore::par_map_shards`, kept exactly for this comparison) —
//!   the per-request thread-spawn tax, most visible at small k / high QPS.
//! * **Scoring**: batch-major blocked scanning (one arena pass per shard
//!   per batch, L1 tiles × the runtime-dispatched multi-query popcount
//!   kernels) vs the scalar per-query heap scan (Q independent arena
//!   passes).
//!
//! `topk_batch/Q64` at the large corpus is the acceptance lane: it runs
//! the production path (executor + blocked + dispatched kernels) against
//! `scoped-scalar/Q64`, the pre-PR baseline reproduced verbatim below.
//! The baseline calls [`cabin::sketch::kernels::scalar`] *explicitly* —
//! the convenience wrappers in `sketch::bitvec` now route through the
//! dispatch table, so going through them would silently benchmark SIMD
//! against SIMD. `kernel/` micro-lanes time each usable ISA arm on the
//! same words so per-arm gains stay visible next to the end-to-end lane.

use cabin::bench::{black_box, Bench};
use cabin::coordinator::protocol::Hit;
use cabin::coordinator::router;
use cabin::coordinator::store::ShardedStore;
use cabin::coordinator::TopK;
use cabin::sketch::cham::binhamming_from_stats;
use cabin::sketch::kernels::{self, scalar::and_count_words};
use cabin::sketch::BitVec;
use cabin::util::rng::Xoshiro256;

const DIM: usize = 1024;
const SHARDS: usize = 4;
const Q: usize = 64;

fn corpus(n: usize) -> Vec<BitVec> {
    let mut rng = Xoshiro256::new(11);
    (0..n)
        .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
        .collect()
}

/// The pre-executor, pre-blocking serving path, verbatim: scoped-spawn
/// scatter + scalar per-query heap scan. Scores with the scalar oracle
/// kernel directly so the baseline stays scalar no matter which arm the
/// dispatch table picked for the production path.
fn scoped_scalar_topk_batch(store: &ShardedStore, queries: &[BitVec], k: usize) -> Vec<Vec<Hit>> {
    let d = store.sketch_dim();
    let wqs: Vec<f64> = queries.iter().map(|q| q.count_ones() as f64).collect();
    let mut per_shard: Vec<Vec<Vec<Hit>>> = store.par_map_shards(|shard| {
        queries
            .iter()
            .zip(&wqs)
            .map(|(q, &wq)| {
                let mut best = TopK::new(k);
                for row in 0..shard.ids.len() {
                    let ip = and_count_words(q.words(), shard.rows.row(row)) as f64;
                    let dist =
                        2.0 * binhamming_from_stats(wq, shard.rows.weight(row) as f64, ip, d);
                    best.offer(shard.ids[row], dist);
                }
                best.into_sorted_hits()
            })
            .collect()
    });
    (0..queries.len())
        .map(|qi| {
            let mut merged: Vec<Hit> = per_shard
                .iter_mut()
                .flat_map(|shard| std::mem::take(&mut shard[qi]))
                .collect();
            merged.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.id.cmp(&b.id)));
            merged.dedup_by(|a, b| a.id == b.id);
            merged.truncate(k);
            merged
        })
        .collect()
}

/// Per-arm micro-lanes: every usable ISA on identical words, so the
/// dispatch win is measurable in isolation from scatter and heap costs.
fn kernel_micro_lanes(b: &mut Bench) {
    const WORDS: usize = 1 << 16; // 4 MiB of operand words per side
    let mut rng = Xoshiro256::new(23);
    let a: Vec<u64> = (0..WORDS).map(|_| rng.next_u64()).collect();
    let v: Vec<u64> = (0..WORDS).map(|_| rng.next_u64()).collect();
    for t in kernels::available() {
        let name = t.isa.name();
        b.bench_with_throughput(&format!("kernel/popcount/{name}"), Some(WORDS as f64), || {
            black_box((t.popcount)(&a));
        });
        b.bench_with_throughput(&format!("kernel/and_count/{name}"), Some(WORDS as f64), || {
            black_box((t.and_count)(&a, &v));
        });
    }
}

fn main() {
    let mut b = Bench::from_env("router");
    let fast = std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1");
    let sizes: &[usize] = if fast { &[20_000] } else { &[100_000, 1_000_000] };

    println!("[bench_router] kernel_isa={}", kernels::active().isa.name());
    kernel_micro_lanes(&mut b);

    for &n in sizes {
        let pts = corpus(n);
        let store = ShardedStore::new(SHARDS, DIM);
        for chunk in pts.chunks(1024) {
            store.insert_batch(chunk.to_vec());
        }
        drop(pts); // the arena owns the corpus now; halve peak memory at 1M
        let mut rng = Xoshiro256::new(5);
        let queries: Vec<BitVec> = (0..Q)
            .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
            .collect();
        let k = 10usize;
        println!("[bench_router] corpus {n} x {DIM} bits, {SHARDS} shards, Q={Q}, k={k}");

        // correctness gate before timing anything: the production path
        // must equal the baseline bit for bit
        assert_eq!(
            router::topk_batch(&store, &queries, k),
            scoped_scalar_topk_batch(&store, &queries, k),
            "blocked/executor path diverged from the scalar baseline"
        );

        // ---- batched: Q queries per call ----
        b.bench_with_throughput(
            &format!("topk_batch/executor-blocked/Q{Q}/{n}"),
            Some((n * Q) as f64),
            || {
                black_box(router::topk_batch(&store, &queries, k));
            },
        );
        b.bench_with_throughput(
            &format!("topk_batch/scoped-scalar/Q{Q}/{n}"),
            Some((n * Q) as f64),
            || {
                black_box(scoped_scalar_topk_batch(&store, &queries, k));
            },
        );

        // ---- single query: the scatter tax dominates at small work ----
        let mut qi = 0usize;
        b.bench_with_throughput(&format!("topk/executor/{n}"), Some(n as f64), || {
            let q = &queries[qi % Q];
            qi += 1;
            black_box(router::topk(&store, q, k));
        });
        let mut qi = 0usize;
        b.bench_with_throughput(&format!("topk/scoped-spawn/{n}"), Some(n as f64), || {
            let q = &queries[qi % Q];
            qi += 1;
            black_box(scoped_scalar_topk_batch(
                &store,
                std::slice::from_ref(q),
                k,
            ));
        });
    }

    b.finish();
}
