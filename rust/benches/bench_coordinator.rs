//! Coordinator benchmarks: batcher ingest throughput (by batch policy),
//! batched vs single-query scatter/gather, and query latency as the corpus
//! grows. The isolated shard-scan kernel comparison lives in `bench_topk`.

use cabin::bench::{black_box, Bench};
use cabin::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, IndexConfig, IndexMode, Request, Response,
};
use cabin::data::synth::SynthSpec;
use std::time::Duration;

fn make_coordinator(max_batch: usize, delay_ms: u64, shards: usize) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        input_dim: 4096,
        num_categories: 64,
        sketch_dim: 1024,
        seed: 42,
        num_shards: shards,
        batcher: BatcherConfig {
            max_batch,
            max_delay: Duration::from_millis(delay_ms),
            queue_cap: 8192,
        },
        use_xla: false, // isolate the native L3 path; XLA lane in bench_heatmap
        heatmap_limit: 10_000,
        // Off, not Auto: Auto still *maintains* shard indexes on every
        // insert, which would tax the ingest numbers. The indexed-vs-full
        // query comparison lives in bench_index.
        index: IndexConfig {
            mode: IndexMode::Off,
            ..Default::default()
        },
        // in-memory: persistence overhead is measured in bench_persist
        persist: Default::default(),
        ..Default::default()
    })
}

fn main() {
    let mut b = Bench::from_env("coordinator");
    let mut spec = SynthSpec::small_demo();
    spec.dim = 4096;
    spec.num_categories = 64;
    spec.num_points = 1000;
    let ds = spec.generate(3);

    // ingest throughput under different batching policies (concurrent
    // producers so batches can actually form)
    for (max_batch, delay_ms) in [(1usize, 0u64), (16, 1), (64, 2)] {
        let c = make_coordinator(max_batch, delay_ms, 4);
        let mut offset = 0usize;
        b.bench_with_throughput(
            &format!("ingest/batch{max_batch}-delay{delay_ms}ms"),
            Some(200.0),
            || {
                let chunk: Vec<_> = (0..200).map(|i| ds.points[(offset + i) % ds.len()].clone()).collect();
                offset += 200;
                let c_ref = &c;
                std::thread::scope(|s| {
                    for part in chunk.chunks(25) {
                        s.spawn(move || {
                            for p in part {
                                match c_ref.handle_request(Request::Insert { vec: p.clone() }) {
                                    Response::Inserted { .. } => {}
                                    other => panic!("{other:?}"),
                                }
                            }
                        });
                    }
                });
            },
        );
        println!(
            "    (mean flushed batch size: {:.1})",
            c.metrics.mean_batch_size()
        );
    }

    // batched vs single-query scatter/gather: one shard visit (and one
    // |q̃| precompute) per batch instead of per query
    for batch in [16usize, 64] {
        let c = make_coordinator(64, 1, 4);
        for p in ds.points.iter().cycle().take(1000) {
            c.handle_request(Request::Insert { vec: p.clone() });
        }
        let mut qi = 0usize;
        b.bench_with_throughput(
            &format!("query_batch/top10/corpus1000/batch{batch}"),
            Some(batch as f64),
            || {
                let vecs: Vec<_> = (0..batch)
                    .map(|i| ds.points[(qi + i) % ds.len()].clone())
                    .collect();
                qi += batch;
                match c.handle_request(Request::QueryBatch { vecs, k: 10 }) {
                    Response::HitsBatch { results } => black_box(results.len()),
                    other => panic!("{other:?}"),
                };
            },
        );
    }

    // query latency vs corpus size and shard count
    for (corpus, shards) in [(500usize, 1usize), (500, 4), (1000, 4)] {
        let c = make_coordinator(64, 1, shards);
        for p in ds.points.iter().cycle().take(corpus) {
            c.handle_request(Request::Insert { vec: p.clone() });
        }
        let mut qi = 0usize;
        b.bench_with_throughput(
            &format!("query/top10/corpus{corpus}/shards{shards}"),
            Some(1.0),
            || {
                let q = &ds.points[qi % ds.len()];
                qi += 1;
                match c.handle_request(Request::Query { vec: q.clone(), k: 10 }) {
                    Response::Hits { hits } => black_box(hits.len()),
                    other => panic!("{other:?}"),
                };
            },
        );
    }

    b.finish();
}
