//! L3 hot-path benchmarks: Cabin sketching and Cham estimation.
//! Backs the §Perf log in EXPERIMENTS.md and the Figure 2/Table 3 scale
//! arguments (per-point sketch cost, per-pair estimate cost).

use cabin::bench::{black_box, Bench};
use cabin::data::synth::SynthSpec;
use cabin::sketch::{cham, BitVec, CabinSketcher, SketchConfig};

fn main() {
    let mut b = Bench::from_env("cham");

    // --- sketching throughput (per-point cost) ---
    let mut spec = SynthSpec::small_demo();
    spec.num_points = 2000;
    spec.dim = 100_000;
    spec.mean_density = 400.0;
    spec.max_density = 871; // NYTimes twin regime
    let ds = spec.generate(3);
    for d in [256usize, 1024, 4096] {
        let sk = CabinSketcher::from_config(SketchConfig::new(ds.dim(), ds.num_categories(), d, 7));
        let mut buf = BitVec::zeros(d);
        b.bench_with_throughput(&format!("sketch/nytimes-twin/d{d}"), Some(ds.len() as f64), || {
            for p in &ds.points {
                sk.sketch_into(p, &mut buf);
                black_box(buf.count_ones());
            }
        });
    }

    // --- pairwise estimate cost (the all-pairs inner loop) ---
    for d in [1000usize, 1024, 4096] {
        let sk = CabinSketcher::from_config(SketchConfig::new(ds.dim(), ds.num_categories(), d, 7));
        let sketches: Vec<BitVec> = ds.points.iter().take(256).map(|p| sk.sketch(p)).collect();
        let cfg = *sk.config();
        let pairs = (sketches.len() * (sketches.len() - 1) / 2) as f64;
        b.bench_with_throughput(&format!("estimate/allpairs-256/d{d}"), Some(pairs), || {
            let mut acc = 0.0;
            for i in 0..sketches.len() {
                for j in (i + 1)..sketches.len() {
                    acc += cham::estimate_hamming(&sketches[i], &sketches[j], &cfg);
                }
            }
            black_box(acc);
        });
    }

    // --- exact categorical HD for contrast (the "78 ms vs 570 µs" axis) ---
    let pairs = (200 * 199 / 2) as f64;
    b.bench_with_throughput("exact/allpairs-200/full-dim", Some(pairs), || {
        let mut acc = 0usize;
        for i in 0..200 {
            for j in (i + 1)..200 {
                acc += ds.points[i].hamming(&ds.points[j]);
            }
        }
        black_box(acc);
    });

    b.finish();
}
