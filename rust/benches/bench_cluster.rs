//! Clustering benchmarks (Figure 10's workload): k-mode on full
//! categorical data vs binary k-mode on Cabin sketches, plus k-means on an
//! LSA embedding for the real-valued lane.

use cabin::baselines::by_key;
use cabin::bench::{black_box, Bench};
use cabin::cluster::{kmeans, kmode, kmode_binary};
use cabin::data::registry::DatasetSpec;

fn main() {
    let mut b = Bench::from_env("cluster");
    let spec = DatasetSpec::by_key("nytimes").unwrap();
    let ds = spec.synth_spec(300).generate(42);
    let k = 5;
    let iters = 15;

    b.bench_with_throughput("kmode/full-dim/300pts", Some(ds.len() as f64), || {
        black_box(kmode(&ds, k, iters, 7).cost);
    });

    let red = by_key("cabin").unwrap().reduce(&ds, 1000, 7);
    let bits = red.as_bits().unwrap().to_vec();
    b.bench_with_throughput("kmode/cabin-d1000/300pts", Some(ds.len() as f64), || {
        black_box(kmode_binary(&bits, k, iters, 7).cost);
    });

    let lsa = by_key("lsa").unwrap().reduce(&ds, 100, 7).to_matrix();
    b.bench_with_throughput("kmeans/lsa-d100/300pts", Some(ds.len() as f64), || {
        black_box(kmeans(&lsa, k, iters, 7).cost);
    });

    b.finish();
}
