//! Heatmap generation benchmarks (Figure 11 / Table 4 workload, §5.5's
//! 136× claim): exact full-dimensional vs native sketch fast-path vs the
//! XLA fused kernel (when artifacts are present).

use cabin::analysis::heatmap::Heatmap;
use cabin::bench::{black_box, Bench};
use cabin::data::synth::SynthSpec;
use cabin::runtime::XlaEngine;
use cabin::sketch::{CabinSketcher, SketchConfig};

fn main() {
    let mut b = Bench::from_env("heatmap");

    // BrainCell-twin regime scaled down: very high dimension, low density.
    let mut spec = SynthSpec::small_demo();
    spec.num_points = 256;
    spec.dim = 200_000;
    spec.mean_density = 500.0;
    spec.max_density = 1051;
    let ds = spec.generate(9);
    let entries = (ds.len() * ds.len()) as f64;

    b.bench_with_throughput("exact/256pts/200k-dim", Some(entries), || {
        black_box(Heatmap::exact(&ds).values[10]);
    });

    let d = 1024;
    let sk = CabinSketcher::from_config(SketchConfig::new(ds.dim(), ds.num_categories(), d, 7));
    let sketches = sk.sketch_dataset(&ds, cabin::util::parallel::default_threads());
    // §Perf before/after: naive (3 logs/pair, static blocks) vs optimized
    // (precomputed inversions + striped rows).
    b.bench_with_throughput("native-naive/256pts/d1024", Some(entries), || {
        black_box(Heatmap::from_sketches_naive(&sketches, 2.0).values[10]);
    });
    b.bench_with_throughput("native-sketch/256pts/d1024", Some(entries), || {
        black_box(Heatmap::from_sketches_occupancy(&sketches, 2.0).values[10]);
    });
    // single-thread lanes isolate the per-pair cost from scheduling
    let big: Vec<_> = (0..2000.min(ds.len() * 8))
        .map(|i| sketches[i % sketches.len()].clone())
        .collect();
    let e_big = (big.len() * big.len()) as f64;
    b.bench_with_throughput("native-sketch/2000pts/d1024", Some(e_big), || {
        black_box(Heatmap::from_sketches_occupancy(&big, 2.0).values[10]);
    });

    // XLA path (single-threaded PJRT CPU; main-thread use is fine here).
    if let Some(engine) = XlaEngine::try_default() {
        let dd = engine.manifest.d;
        let skx = CabinSketcher::from_config(SketchConfig::new(
            ds.dim(),
            ds.num_categories(),
            dd,
            engine.manifest.seed,
        ));
        let sketches_mp: Vec<_> = ds
            .points
            .iter()
            .take(engine.manifest.mp)
            .map(|p| skx.sketch(p))
            .collect();
        let e2 = (sketches_mp.len() * sketches_mp.len()) as f64;
        b.bench_with_throughput("xla-allpairs/256pts/d1024", Some(e2), || {
            black_box(engine.cham_allpairs(&sketches_mp).unwrap()[10]);
        });
    } else {
        println!("[bench_heatmap] artifacts missing — skipping xla lane (run `make artifacts`)");
    }

    b.finish();
}
