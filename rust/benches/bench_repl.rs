//! Replication benchmarks: what a follower costs and what a replica buys.
//!
//! * **Catch-up lag** — wall time for a fresh follower to bootstrap from
//!   a live primary and reach per-shard seq parity, from a pure WAL
//!   (generation 0: every frame ships) and from a snapshot (one arena
//!   transfer + an empty tail) — the two ends of the
//!   `--wal-max-bytes`/`snapshot_every` trade-off a follower fleet cares
//!   about.
//! * **Replica serving** — `query_batch` throughput answered entirely by
//!   the replica's own store + LSH indexes (the read fan-out the
//!   subsystem exists to provide).
//!
//! Fast mode (`CABIN_BENCH_FAST=1`, the CI lane) runs a 10k-row corpus;
//! the full run uses 100k.

use cabin::bench::{black_box, Bench};
use cabin::coordinator::{Coordinator, CoordinatorConfig, Request, Response};
use cabin::data::CatVector;
use cabin::persist::{FsyncPolicy, PersistConfig, PersistMode};
use cabin::sketch::BitVec;
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

const INPUT_DIM: usize = 512;
const CATS: u16 = 8;
const SKETCH_DIM: usize = 256;
const SHARDS: usize = 4;
const Q: usize = 64;

fn config(dir: &TempDir) -> CoordinatorConfig {
    CoordinatorConfig {
        input_dim: INPUT_DIM,
        num_categories: CATS,
        sketch_dim: SKETCH_DIM,
        seed: 9,
        num_shards: SHARDS,
        use_xla: false,
        persist: PersistConfig {
            mode: PersistMode::WalSnapshot,
            data_dir: Some(dir.path().to_path_buf()),
            fsync: FsyncPolicy::Never,
            snapshot_every: 0, // rotations only where the bench forces them
            commit_window_us: 0,
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        },
        ..Default::default()
    }
}

fn serve(config: CoordinatorConfig) -> (SocketAddr, Arc<Coordinator>) {
    let coordinator = Arc::new(Coordinator::try_new(config).unwrap());
    let (tx, rx) = std::sync::mpsc::sync_channel(1);
    let server = Arc::clone(&coordinator);
    // detached on purpose: the bench process exit tears the server down
    let _ = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", |addr| {
                let _ = tx.send(addr);
            })
            .unwrap();
    });
    (rx.recv().unwrap(), coordinator)
}

/// Block until the follower's durable seqs match `target` on every shard.
fn await_parity(follower: &Coordinator, target: &[u64]) {
    let p = follower.store.persistence().unwrap();
    loop {
        if (0..SHARDS).all(|si| p.committed_seq(si) >= target[si]) {
            return;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
}

/// One full follower life: bootstrap + catch up to `target`, then drop.
fn follower_catchup(primary: SocketAddr, target: &[u64]) {
    let dir = TempDir::new("bench-repl-follower");
    let follower = Coordinator::try_new(CoordinatorConfig {
        replicate_from: Some(primary.to_string()),
        repl_poll_ms: 1,
        ..config(&dir)
    })
    .unwrap();
    await_parity(&follower, target);
}

fn main() {
    let fast = std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1");
    let n: usize = if fast { 10_000 } else { 100_000 };
    let mut b = Bench::from_env("repl");

    let p_dir = TempDir::new("bench-repl-primary");
    let (addr, primary) = serve(config(&p_dir));
    // bulk-ingest through the store (the WAL sees the same frames the
    // wire path would write; the bench measures shipping, not sketching)
    let mut rng = Xoshiro256::new(5);
    let mut batch = Vec::with_capacity(512);
    for _ in 0..n {
        batch.push(BitVec::from_indices(
            SKETCH_DIM,
            rng.sample_indices(SKETCH_DIM, 32),
        ));
        if batch.len() == 512 {
            primary.store.insert_batch(std::mem::take(&mut batch));
        }
    }
    if !batch.is_empty() {
        primary.store.insert_batch(batch);
    }
    let p = primary.store.persistence().unwrap();
    let target: Vec<u64> = (0..SHARDS).map(|si| p.committed_seq(si)).collect();
    assert_eq!(target.iter().sum::<u64>(), n as u64);

    // generation 0: the whole corpus ships as WAL frames
    b.bench_with_throughput(&format!("catchup_wal/{n}"), Some(n as f64), || {
        follower_catchup(addr, &target);
    });

    // after a rotation the same corpus ships as one snapshot payload
    primary.store.persist_snapshot().unwrap();
    b.bench_with_throughput(&format!("catchup_snapshot/{n}"), Some(n as f64), || {
        follower_catchup(addr, &target);
    });

    // replica serving: a caught-up follower answers batched top-k alone
    let f_dir = TempDir::new("bench-repl-serving");
    let follower = Coordinator::try_new(CoordinatorConfig {
        replicate_from: Some(addr.to_string()),
        repl_poll_ms: 1,
        ..config(&f_dir)
    })
    .unwrap();
    await_parity(&follower, &target);
    let mut rng = Xoshiro256::new(6);
    let queries: Vec<CatVector> = (0..Q)
        .map(|_| CatVector::random(INPUT_DIM, 40, CATS, &mut rng))
        .collect();
    b.bench_with_throughput(
        &format!("replica_query_batch/{n}/Q{Q}"),
        Some(Q as f64),
        || {
            let resp = follower.handle_request(Request::QueryBatch {
                vecs: queries.clone(),
                k: 10,
            });
            match resp {
                Response::HitsBatch { results } => {
                    assert_eq!(results.len(), Q);
                    black_box(&results);
                }
                other => panic!("{other:?}"),
            }
        },
    );

    b.finish();
}
