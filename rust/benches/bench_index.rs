//! Sublinear vs exhaustive top-k: the per-shard multi-probe Hamming-LSH
//! candidate path against the full arena heap scan, on a ≥100k-sketch
//! clustered corpus (downscaled under `CABIN_BENCH_FAST=1`). Also reports
//! recall@10 of the indexed path against the full scan — the bench refuses
//! to run a configuration whose recall gate (≥ 0.9) fails, so the speed
//! numbers can never come from a broken index.

use cabin::bench::{black_box, Bench};
use cabin::coordinator::router::{self, QueryOpts};
use cabin::coordinator::store::ShardedStore;
use cabin::coordinator::IndexCounters;
use cabin::index::{IndexConfig, IndexMode};
use cabin::sketch::BitVec;
use cabin::util::rng::Xoshiro256;
use std::sync::atomic::Ordering;

const DIM: usize = 1024;
const ONES: usize = 128;

fn random_sketch(rng: &mut Xoshiro256) -> BitVec {
    BitVec::from_indices(DIM, rng.sample_indices(DIM, ONES))
}

fn perturb(center: &BitVec, flips: usize, rng: &mut Xoshiro256) -> BitVec {
    let mut v = center.clone();
    for _ in 0..flips {
        let i = rng.gen_range(DIM as u64) as usize;
        if v.get(i) {
            v.clear(i);
        } else {
            v.set(i);
        }
    }
    v
}

fn main() {
    let mut b = Bench::from_env("index");
    let fast = std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1");
    let n: usize = if fast { 20_000 } else { 100_000 };
    let cluster_size = 20usize;
    let centers_n = n / (2 * cluster_size); // half the corpus is clustered
    let mut rng = Xoshiro256::new(7);

    let isa = cabin::sketch::kernels::active().isa.name();
    println!("[bench_index] kernel_isa={isa}");
    println!("[bench_index] building {n}-sketch corpus (d={DIM}, {centers_n} clusters)");
    let centers: Vec<BitVec> = (0..centers_n).map(|_| random_sketch(&mut rng)).collect();
    let mut corpus: Vec<BitVec> = Vec::with_capacity(n);
    for c in &centers {
        for _ in 0..cluster_size {
            corpus.push(perturb(c, 12, &mut rng));
        }
    }
    while corpus.len() < n {
        corpus.push(random_sketch(&mut rng));
    }

    let cfg = IndexConfig {
        mode: IndexMode::On,
        ..Default::default()
    };
    let store = ShardedStore::with_index(4, DIM, &cfg, 42);
    for chunk in corpus.chunks(1024) {
        store.insert_batch(chunk.to_vec());
    }
    let queries: Vec<BitVec> = (0..32)
        .map(|i| perturb(&centers[(i * 37) % centers.len()], 6, &mut rng))
        .collect();

    // ---- recall gate: indexed top-10 vs full-scan top-10 ----
    let k = 10usize;
    let counters = std::sync::Arc::new(IndexCounters::default());
    let opts = QueryOpts::indexed(0, Some(counters.clone()));
    let (mut hit, mut total) = (0usize, 0usize);
    for q in &queries {
        let exact: Vec<usize> = router::topk(&store, q, k).iter().map(|h| h.id).collect();
        let indexed: Vec<usize> = router::topk_with(&store, q, k, &opts)
            .iter()
            .map(|h| h.id)
            .collect();
        total += exact.len();
        hit += exact.iter().filter(|id| indexed.contains(*id)).count();
    }
    let recall = hit as f64 / total as f64;
    let scanned_frac = counters.reranked.load(Ordering::Relaxed) as f64
        / (queries.len() as f64 * n as f64);
    println!(
        "[bench_index] recall@{k} = {recall:.4} ({hit}/{total}); candidates reranked: {:.2}% of corpus/query; fallbacks: {}",
        100.0 * scanned_frac,
        counters.fallbacks.load(Ordering::Relaxed)
    );
    assert!(
        recall >= 0.9,
        "recall gate failed: {recall:.3} < 0.9 — not benching a broken index"
    );

    // ---- throughput: full scan vs indexed ----
    for k in [10usize, 100] {
        let mut qi = 0usize;
        b.bench_with_throughput(
            &format!("router/full-scan/{n}/4shards/k{k}"),
            Some(n as f64),
            || {
                let q = &queries[qi % queries.len()];
                qi += 1;
                black_box(router::topk(&store, q, k).len());
            },
        );
        let mut qi = 0usize;
        let bench_opts = QueryOpts::indexed(0, None);
        b.bench_with_throughput(
            &format!("router/lsh-indexed/{n}/4shards/k{k}"),
            Some(n as f64),
            || {
                let q = &queries[qi % queries.len()];
                qi += 1;
                black_box(router::topk_with(&store, q, k, &bench_opts).len());
            },
        );
    }

    // ---- batched scatter on both paths ----
    let batch: Vec<BitVec> = queries[..16].to_vec();
    b.bench_with_throughput(
        &format!("router/full-scan-batch16/{n}/k10"),
        Some(16.0 * n as f64),
        || {
            black_box(router::topk_batch(&store, &batch, 10).len());
        },
    );
    let bench_opts = QueryOpts::indexed(0, None);
    b.bench_with_throughput(
        &format!("router/lsh-indexed-batch16/{n}/k10"),
        Some(16.0 * n as f64),
        || {
            black_box(router::topk_batch_with(&store, &batch, 10, &bench_opts).len());
        },
    );

    b.finish();
}
