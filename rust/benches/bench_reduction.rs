//! Dimensionality-reduction timing per method (the Table 3 / Figure 2
//! workload at bench scale): one lane per method on a KOS-twin sample.

use cabin::baselines::{by_key, ALL_KEYS};
use cabin::bench::{black_box, Bench, BenchConfig};
use cabin::data::registry::DatasetSpec;

fn main() {
    let mut b = Bench::new(
        "reduction",
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            min_secs: 0.2,
            max_secs: 20.0,
        },
    );
    let spec = DatasetSpec::by_key("kos").unwrap();
    let ds = spec.synth_spec(200).generate(42);
    let d = 256;
    for key in ALL_KEYS {
        // NNMF/LDA/VAE are slow by design — they get fewer iterations via
        // the max_secs cap; that is the point of the comparison.
        let r = by_key(key).unwrap();
        b.bench_with_throughput(&format!("{key}/kos200/d{d}"), Some(ds.len() as f64), || {
            black_box(r.reduce(&ds, d, 7).len());
        });
    }
    b.finish();
}
