//! Persistence benchmarks: what the WAL costs on the ingest path (per
//! fsync policy), what group commit buys back under concurrent ingest,
//! what a snapshot rotation costs, and how fast recovery is from a pure
//! WAL vs from a snapshot + empty tail — the numbers that justify
//! `wal+snapshot` as the `--data-dir` default and ~1 ms as the
//! `--commit-window-us` default.

use cabin::bench::{black_box, Bench};
use cabin::coordinator::store::ShardedStore;
use cabin::coordinator::ExecutorConfig;
use cabin::index::{IndexConfig, IndexMode};
use cabin::persist::{Fingerprint, FsyncPolicy, PersistConfig, PersistCounters, PersistMode};
use cabin::sketch::BitVec;
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::sync::Arc;

const DIM: usize = 1024;
const BATCH: usize = 64;
const SHARDS: usize = 4;

fn corpus(n: usize) -> Vec<BitVec> {
    let mut rng = Xoshiro256::new(7);
    (0..n)
        .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
        .collect()
}

fn no_index() -> IndexConfig {
    IndexConfig {
        mode: IndexMode::Off,
        ..Default::default()
    }
}

fn fingerprint() -> Fingerprint {
    Fingerprint {
        sketch_dim: DIM,
        seed: 7,
        num_shards: SHARDS,
        input_dim: 4 * DIM,
        num_categories: 64,
    }
}

fn durable_cfg(dir: &TempDir, mode: PersistMode, fsync: FsyncPolicy, every: u64) -> PersistConfig {
    PersistConfig {
        mode,
        data_dir: Some(dir.path().to_path_buf()),
        fsync,
        snapshot_every: every,
        // per-batch commits by default: the group-commit lanes set their
        // own window explicitly so the two policies are benched apart
        commit_window_us: 0,
        wal_max_bytes: 0,
        compact_dead_frames: 0,
    }
}

fn open(cfg: &PersistConfig) -> ShardedStore {
    ShardedStore::open_durable(
        fingerprint(),
        &no_index(),
        cfg,
        Arc::new(PersistCounters::default()),
        &ExecutorConfig::default(),
    )
    .map(|(store, _)| store)
    .unwrap()
}

fn ingest(store: &ShardedStore, pts: &[BitVec]) {
    for chunk in pts.chunks(BATCH) {
        black_box(store.insert_batch(chunk.to_vec()));
    }
}

fn main() {
    let mut b = Bench::from_env("persist");
    let fast = std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1");
    let n: usize = if fast { 4_000 } else { 40_000 };
    let pts = corpus(n);
    println!("[bench_persist] {n}-sketch corpus, d={DIM}, batches of {BATCH}");

    // ingest cost by persistence mode: the WAL tax and the fsync tax.
    // Every iteration gets a fresh data dir (recovery of a stale one
    // would otherwise pollute the measurement).
    b.bench_with_throughput(&format!("ingest/off/{n}"), Some(n as f64), || {
        let store = ShardedStore::with_index(SHARDS, DIM, &no_index(), 7);
        ingest(&store, &pts);
    });
    b.bench_with_throughput(
        &format!("ingest/wal-fsync-never/{n}"),
        Some(n as f64),
        || {
            let dir = TempDir::new("bench-wal-never");
            let store = open(&durable_cfg(&dir, PersistMode::Wal, FsyncPolicy::Never, 0));
            ingest(&store, &pts);
        },
    );
    b.bench_with_throughput(
        &format!("ingest/wal-fsync-always/{n}"),
        Some(n as f64),
        || {
            let dir = TempDir::new("bench-wal-always");
            let store = open(&durable_cfg(&dir, PersistMode::Wal, FsyncPolicy::Always, 0));
            ingest(&store, &pts);
        },
    );
    b.bench_with_throughput(
        &format!("ingest/wal+snapshot/{n}"),
        Some(n as f64),
        || {
            let dir = TempDir::new("bench-wal-snap");
            let store = open(&durable_cfg(
                &dir,
                PersistMode::WalSnapshot,
                FsyncPolicy::Never,
                (n / 2) as u64, // one mid-stream rotation
            ));
            ingest(&store, &pts);
        },
    );

    // Group-commit coalescing under concurrent ingest: T writer threads
    // race batches into a durable fsync=always store, per-batch commits
    // vs a 1 ms commit window (one fsync per touched shard per window).
    // The window lane's throughput gain over per-batch IS the amortised
    // fsync tax.
    let writers = 4usize;
    for (label, window_us) in [("per-batch", 0u64), ("window-1ms", 1_000)] {
        b.bench_with_throughput(
            &format!("ingest-concurrent/{writers}w/{label}/{n}"),
            Some(n as f64),
            || {
                let dir = TempDir::new("bench-group-commit");
                let cfg = PersistConfig {
                    commit_window_us: window_us,
                    ..durable_cfg(&dir, PersistMode::Wal, FsyncPolicy::Always, 0)
                };
                let store = open(&cfg);
                let counters = Arc::new(std::sync::atomic::AtomicUsize::new(0));
                std::thread::scope(|scope| {
                    for w in 0..writers {
                        let store = &store;
                        let counters = counters.clone();
                        let pts = &pts;
                        scope.spawn(move || {
                            // interleave: writer w takes batches w, w+T, ...
                            for chunk in pts.chunks(BATCH).skip(w).step_by(writers) {
                                store
                                    .try_insert_batch(chunk.to_vec())
                                    .expect("durable ingest");
                                counters.fetch_add(
                                    chunk.len(),
                                    std::sync::atomic::Ordering::Relaxed,
                                );
                            }
                        });
                    }
                });
                assert_eq!(
                    counters.load(std::sync::atomic::Ordering::Relaxed),
                    pts.len()
                );
                black_box(store.len());
            },
        );
    }

    // a full snapshot rotation of the loaded store, in isolation
    {
        let dir = TempDir::new("bench-rotate");
        let cfg = durable_cfg(&dir, PersistMode::WalSnapshot, FsyncPolicy::Never, 0);
        let store = open(&cfg);
        ingest(&store, &pts);
        b.bench_with_throughput(&format!("snapshot/rotate/{n}"), Some(n as f64), || {
            black_box(store.persist_snapshot().unwrap());
        });
    }

    // recovery: replaying a pure WAL vs loading a snapshot + empty tail
    {
        let wal_dir = TempDir::new("bench-recover-wal");
        let cfg = durable_cfg(&wal_dir, PersistMode::Wal, FsyncPolicy::Never, 0);
        {
            let store = open(&cfg);
            ingest(&store, &pts);
        }
        b.bench_with_throughput(&format!("recover/wal/{n}"), Some(n as f64), || {
            let store = open(&cfg);
            assert_eq!(store.len(), n);
            black_box(store.len());
        });

        let snap_dir = TempDir::new("bench-recover-snap");
        let cfg = durable_cfg(&snap_dir, PersistMode::WalSnapshot, FsyncPolicy::Never, 0);
        {
            let store = open(&cfg);
            ingest(&store, &pts);
            store.persist_snapshot().unwrap();
        }
        b.bench_with_throughput(&format!("recover/snapshot/{n}"), Some(n as f64), || {
            let store = open(&cfg);
            assert_eq!(store.len(), n);
            black_box(store.len());
        });

        // recovery with the LSH index on: adds the bulk rebuild cost
        let ix_dir = TempDir::new("bench-recover-indexed");
        let on = IndexConfig {
            mode: IndexMode::On,
            ..Default::default()
        };
        let cfg = durable_cfg(&ix_dir, PersistMode::WalSnapshot, FsyncPolicy::Never, 0);
        {
            let (store, _) = ShardedStore::open_durable(
                fingerprint(),
                &on,
                &cfg,
                Arc::new(PersistCounters::default()),
                &ExecutorConfig::default(),
            )
            .unwrap();
            ingest(&store, &pts);
            store.persist_snapshot().unwrap();
        }
        b.bench_with_throughput(
            &format!("recover/snapshot-indexed/{n}"),
            Some(n as f64),
            || {
                let (store, _) = ShardedStore::open_durable(
                    fingerprint(),
                    &on,
                    &cfg,
                    Arc::new(PersistCounters::default()),
                    &ExecutorConfig::default(),
                )
                .unwrap();
                assert_eq!(store.len(), n);
                black_box(store.len());
            },
        );
    }

    b.finish();
}
