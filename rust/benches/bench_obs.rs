//! Observability overhead benchmarks — the cost of watching the serving
//! path must stay negligible.
//!
//! * **Histogram recording**: raw [`cabin::obs::ObsHistogram::record_us`]
//!   throughput — four relaxed atomic RMWs per sample, the unit cost
//!   every instrumented stage pays.
//! * **Routed query tax** (the acceptance lane): `routed_query/baseline`
//!   runs the production batched read path with no observer attached;
//!   `routed_query/instrumented` attaches the full stage-histogram set
//!   plus a per-request [`cabin::obs::ReadSpan`] — exactly what the
//!   server does per query. The instrumented p50 must stay within 5% of
//!   baseline (the gate in `tools/bench_gate.py` holds each lane to its
//!   own history; the ratio printed here makes the tax visible in one
//!   run).

use cabin::bench::{black_box, Bench};
use cabin::coordinator::router::{self, QueryOpts};
use cabin::coordinator::store::ShardedStore;
use cabin::obs::{ObsHistogram, ReadSpan, Stages};
use cabin::sketch::BitVec;
use cabin::util::rng::Xoshiro256;
use std::sync::Arc;

const DIM: usize = 1024;
const SHARDS: usize = 4;
const Q: usize = 64;

fn corpus(n: usize) -> Vec<BitVec> {
    let mut rng = Xoshiro256::new(11);
    (0..n)
        .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
        .collect()
}

fn main() {
    let mut b = Bench::from_env("obs");
    let fast = std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1");

    // ---- unit cost: one histogram sample ----
    let hist = ObsHistogram::new();
    let mut us = 1u64;
    b.bench_with_throughput("histogram/record_us", Some(1.0), || {
        // stride through the bucket range so the branchy index path is
        // exercised, not one hot bucket
        us = us.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
        hist.record_us(black_box(us >> 44));
    });

    // ---- serving-path tax: observed vs unobserved routed queries ----
    let n = if fast { 20_000 } else { 200_000 };
    let pts = corpus(n);
    let store = ShardedStore::new(SHARDS, DIM);
    for chunk in pts.chunks(1024) {
        store.insert_batch(chunk.to_vec());
    }
    drop(pts);
    let mut rng = Xoshiro256::new(5);
    let queries: Vec<BitVec> = (0..Q)
        .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
        .collect();
    let k = 10usize;
    println!("[bench_obs] corpus {n} x {DIM} bits, {SHARDS} shards, Q={Q}, k={k}");

    let plain = QueryOpts::full_scan();
    let stages = Arc::new(Stages::new());
    // observation must never change results
    assert_eq!(
        router::topk_batch_with(&store, &queries, k, &plain),
        router::topk_batch_with(
            &store,
            &queries,
            k,
            &QueryOpts::full_scan()
                .with_observer(Arc::clone(&stages), Some(Arc::new(ReadSpan::default())))
        ),
        "instrumented path diverged from baseline"
    );

    let base_mean = b.bench_with_throughput(
        &format!("routed_query/baseline/{n}"),
        Some((n * Q) as f64),
        || {
            black_box(router::topk_batch_with(&store, &queries, k, &plain));
        },
    );
    let inst_mean = b.bench_with_throughput(
        &format!("routed_query/instrumented/{n}"),
        Some((n * Q) as f64),
        || {
            let opts = QueryOpts::full_scan()
                .with_observer(Arc::clone(&stages), Some(Arc::new(ReadSpan::default())));
            black_box(router::topk_batch_with(&store, &queries, k, &opts));
        },
    );
    let overhead_pct = (inst_mean / base_mean - 1.0) * 100.0;
    println!(
        "[bench_obs] instrumentation overhead: {overhead_pct:+.2}% \
         (baseline {base_mean:.6}s, instrumented {inst_mean:.6}s; budget 5%)"
    );
    println!(
        "[bench_obs] stage samples recorded: read_queue={} read_scan={} read_gather={}",
        stages.read_queue.count(),
        stages.read_scan.count(),
        stages.read_gather.count()
    );

    b.finish();
}
