//! Shard-scan top-k benchmarks: the seed's per-sketch `Vec<BitVec>` layout
//! with a sort-on-every-insert candidate buffer vs the contiguous
//! [`SketchMatrix`] arena scanned with the bounded-heap [`TopK`] kernel,
//! plus the end-to-end sharded router path. Corpus is ≥100k sketches
//! (downscaled under `CABIN_BENCH_FAST=1` so CI stays quick); throughput
//! is reported in candidates/sec.

use cabin::bench::{black_box, Bench};
use cabin::coordinator::store::ShardedStore;
use cabin::coordinator::{router, TopK};
use cabin::sketch::bitvec::and_count_words;
use cabin::sketch::cham::binhamming_from_stats;
use cabin::sketch::{BitVec, SketchMatrix};
use cabin::util::rng::Xoshiro256;

const DIM: usize = 1024;

/// The seed kernel, verbatim layout: one heap-boxed `BitVec` per
/// candidate, weight recomputed per candidate, and a bounded buffer that
/// re-sorts on every accepted insertion. (Comparator upgraded to
/// `total_cmp` so the baseline cannot panic; the cost is identical.)
fn seed_scan(sketches: &[BitVec], query: &BitVec, wq: f64, k: usize) -> Vec<(usize, f64)> {
    let mut hits: Vec<(usize, f64)> = Vec::with_capacity(k + 1);
    for (id, sk) in sketches.iter().enumerate() {
        let ip = query.and_count(sk) as f64;
        let dist = 2.0 * binhamming_from_stats(wq, sk.count_ones() as f64, ip, DIM);
        if hits.len() < k {
            hits.push((id, dist));
            hits.sort_by(|a, b| a.1.total_cmp(&b.1));
        } else if dist < hits[k - 1].1 {
            hits[k - 1] = (id, dist);
            hits.sort_by(|a, b| a.1.total_cmp(&b.1));
        }
    }
    hits
}

/// The arena kernel: borrowed `&[u64]` rows, cached row weights, bounded
/// max-heap selection — zero per-candidate allocations.
fn arena_scan(m: &SketchMatrix, query: &BitVec, wq: f64, k: usize) -> Vec<(usize, f64)> {
    let mut best = TopK::new(k);
    let qw = query.words();
    for (i, row) in m.rows().enumerate() {
        let ip = and_count_words(qw, row) as f64;
        best.offer(i, 2.0 * binhamming_from_stats(wq, m.weight(i) as f64, ip, DIM));
    }
    best.into_sorted_hits()
        .into_iter()
        .map(|h| (h.id, h.dist))
        .collect()
}

fn main() {
    let mut b = Bench::from_env("topk");
    let n: usize = if std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1") {
        20_000
    } else {
        100_000
    };
    let mut rng = Xoshiro256::new(7);
    let isa = cabin::sketch::kernels::active().isa.name();
    println!("[bench_topk] kernel_isa={isa}");
    println!("[bench_topk] building {n}-sketch corpus (d={DIM})");
    let sketches: Vec<BitVec> = (0..n)
        .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
        .collect();
    let arena = SketchMatrix::from_sketches(&sketches);
    let queries: Vec<BitVec> = (0..16)
        .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
        .collect();

    // sanity: both kernels must select identical (id, dist) sets
    for q in &queries {
        let wq = q.count_ones() as f64;
        assert_eq!(seed_scan(&sketches, q, wq, 10), arena_scan(&arena, q, wq, 10));
    }

    for k in [10usize, 100] {
        let mut qi = 0usize;
        b.bench_with_throughput(&format!("scan/seed-vec-sort/{n}/k{k}"), Some(n as f64), || {
            let q = &queries[qi % queries.len()];
            qi += 1;
            black_box(seed_scan(&sketches, q, q.count_ones() as f64, k).len());
        });
        let mut qi = 0usize;
        b.bench_with_throughput(&format!("scan/arena-heap/{n}/k{k}"), Some(n as f64), || {
            let q = &queries[qi % queries.len()];
            qi += 1;
            black_box(arena_scan(&arena, q, q.count_ones() as f64, k).len());
        });
    }

    // end-to-end router path: 4 arena shards, parallel scatter/gather
    let store = ShardedStore::new(4, DIM);
    for chunk in sketches.chunks(1024) {
        store.insert_batch(chunk.to_vec());
    }
    let mut qi = 0usize;
    b.bench_with_throughput(&format!("router/topk/{n}/4shards/k10"), Some(n as f64), || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        black_box(router::topk(&store, q, 10).len());
    });
    let mut qi = 0usize;
    b.bench_with_throughput(
        &format!("router/topk_batch/{n}/4shards/k10/batch16"),
        Some(16.0 * n as f64),
        || {
            let qs: Vec<BitVec> = (0..16)
                .map(|i| queries[(qi + i) % queries.len()].clone())
                .collect();
            qi += 16;
            black_box(router::topk_batch(&store, &qs, 10).len());
        },
    );

    b.finish();
}
