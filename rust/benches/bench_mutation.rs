//! Mutable-corpus benchmarks: what a delete (swap-remove + index patch)
//! and an upsert (re-sketch + in-place overwrite) cost at steady state,
//! what the WAL adds to a mixed mutation stream, how fast recovery
//! replays a delete-heavy log, and what the compaction fold — an
//! ordinary snapshot rotation over the survivors — pauses for.

use cabin::bench::{black_box, Bench};
use cabin::coordinator::store::ShardedStore;
use cabin::coordinator::ExecutorConfig;
use cabin::index::{IndexConfig, IndexMode};
use cabin::persist::{Fingerprint, FsyncPolicy, PersistConfig, PersistCounters, PersistMode};
use cabin::sketch::BitVec;
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::collections::VecDeque;
use std::sync::Arc;

const DIM: usize = 1024;
const SHARDS: usize = 4;

fn corpus(n: usize) -> Vec<BitVec> {
    let mut rng = Xoshiro256::new(7);
    (0..n)
        .map(|_| BitVec::from_indices(DIM, rng.sample_indices(DIM, 128)))
        .collect()
}

fn no_index() -> IndexConfig {
    IndexConfig {
        mode: IndexMode::Off,
        ..Default::default()
    }
}

fn fingerprint() -> Fingerprint {
    Fingerprint {
        sketch_dim: DIM,
        seed: 7,
        num_shards: SHARDS,
        input_dim: 4 * DIM,
        num_categories: 64,
    }
}

fn durable_cfg(dir: &TempDir, mode: PersistMode) -> PersistConfig {
    PersistConfig {
        mode,
        data_dir: Some(dir.path().to_path_buf()),
        fsync: FsyncPolicy::Never,
        snapshot_every: 0, // rotations only where a lane forces them
        commit_window_us: 0,
        wal_max_bytes: 0,
        compact_dead_frames: 0,
    }
}

fn open(cfg: &PersistConfig) -> ShardedStore {
    ShardedStore::open_durable(
        fingerprint(),
        &no_index(),
        cfg,
        Arc::new(PersistCounters::default()),
        &ExecutorConfig::default(),
    )
    .map(|(store, _)| store)
    .unwrap()
}

/// Ingest, then retire every third row and overwrite every fifth — the
/// delete-heavy history the recovery and compaction lanes replay.
fn mixed_history(store: &ShardedStore, pts: &[BitVec]) -> usize {
    let ids = store.insert_batch(pts.to_vec());
    let mut live = ids.len();
    for (i, id) in ids.iter().enumerate() {
        if i % 3 == 0 {
            store.delete(*id).unwrap();
            live -= 1;
        } else if i % 5 == 0 {
            store.upsert(*id, pts[(i + 1) % pts.len()].clone(), 0).unwrap();
        }
    }
    live
}

fn main() {
    let mut b = Bench::from_env("mutation");
    let fast = std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1");
    let n: usize = if fast { 2_000 } else { 20_000 };
    let pts = corpus(n);
    println!("[bench_mutation] {n}-sketch corpus, d={DIM}, {SHARDS} shards");

    // steady-state churn: delete the oldest row, insert a fresh one —
    // the swap-remove + id-index patch + placement cost per replaced
    // row, with the LSH index both off and on (the indexed lane adds the
    // O(L) bucket removals/appends under the same shard lock)
    for (label, mode) in [("scan", IndexMode::Off), ("indexed", IndexMode::On)] {
        let cfg = IndexConfig {
            mode,
            ..Default::default()
        };
        let store = ShardedStore::with_index(SHARDS, DIM, &cfg, 7);
        let mut live: VecDeque<usize> = store.insert_batch(pts.clone()).into();
        let mut next = 0usize;
        let ops = n / 4;
        b.bench_with_throughput(
            &format!("churn/delete+insert/{label}/{ops}"),
            Some(ops as f64),
            || {
                for _ in 0..ops {
                    let id = live.pop_front().unwrap();
                    store.delete(id).unwrap();
                    live.push_back(store.insert_batch(vec![pts[next % n].clone()])[0]);
                    next += 1;
                }
                black_box(store.live_len());
            },
        );
    }

    // steady-state upsert: same id, new row — re-sketching is the
    // caller's cost here, so this isolates overwrite + weight + index
    // maintenance
    {
        let store = ShardedStore::with_index(SHARDS, DIM, &no_index(), 7);
        let ids = store.insert_batch(pts.clone());
        let ops = n / 4;
        let mut round = 0usize;
        b.bench_with_throughput(
            &format!("upsert/in-place/{ops}"),
            Some(ops as f64),
            || {
                for (i, id) in ids.iter().take(ops).enumerate() {
                    store
                        .upsert(*id, pts[(i + round + 1) % n].clone(), 0)
                        .unwrap();
                }
                round += 1;
                black_box(store.live_len());
            },
        );
    }

    // the WAL tax on a mixed mutation stream (fresh dir per iteration so
    // recovery never pollutes the measurement)
    b.bench_with_throughput(
        &format!("ingest-mixed/wal-fsync-never/{n}"),
        Some(n as f64),
        || {
            let dir = TempDir::new("bench-mut-wal");
            let store = open(&durable_cfg(&dir, PersistMode::Wal));
            black_box(mixed_history(&store, &pts));
        },
    );

    // recovery of the mixed log — replaying deletes and upserts record
    // by record — then the compaction fold (a snapshot rotation over the
    // survivors) and recovery from the folded generation
    {
        let dir = TempDir::new("bench-mut-recover");
        let cfg = durable_cfg(&dir, PersistMode::WalSnapshot);
        let live = {
            let store = open(&cfg);
            mixed_history(&store, &pts)
        };
        b.bench_with_throughput(&format!("recover/mixed-wal/{n}"), Some(n as f64), || {
            let store = open(&cfg);
            assert_eq!(store.live_len(), live);
            black_box(store.live_len());
        });

        let store = open(&cfg);
        b.bench_with_throughput(
            &format!("compact/fold-rotation/{live}"),
            Some(live as f64),
            || {
                black_box(store.persist_snapshot().unwrap());
            },
        );
        drop(store);
        b.bench_with_throughput(
            &format!("recover/compacted/{live}"),
            Some(live as f64),
            || {
                let store = open(&cfg);
                assert_eq!(store.live_len(), live);
                black_box(store.live_len());
            },
        );
    }

    b.finish();
}
