//! Concurrency soak: top-k queries (full-scan *and* LSH-indexed) and O(1)
//! distance lookups racing batched inserts and rebalances on the arena
//! store. The invariants under fire: no id is ever lost, no query result
//! contains a duplicate or unsorted hit, every settled id resolves to the
//! sketch that was inserted under it, shard occupancy stays level, and the
//! per-shard LSH indexes (appended by inserts, remove-last/append-updated
//! by rebalance moves) never desync from their arenas.

use cabin::coordinator::router::{self, QueryOpts};
use cabin::coordinator::store::ShardedStore;
use cabin::index::{IndexConfig, IndexMode};
use cabin::sketch::BitVec;
use cabin::util::rng::Xoshiro256;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

const DIM: usize = 128;

fn sketch(rng: &mut Xoshiro256) -> BitVec {
    let ones = 1 + rng.gen_range((DIM / 4) as u64) as usize;
    BitVec::from_indices(DIM, rng.sample_indices(DIM, ones))
}

#[test]
fn soak_queries_and_lookups_race_inserts_and_rebalance() {
    // indexed store: the soak then also exercises incremental index
    // appends racing rebalance-move index updates
    let cfg = IndexConfig {
        mode: IndexMode::On,
        ..Default::default()
    };
    let store = ShardedStore::with_index(4, DIM, &cfg, 13);
    let done = AtomicBool::new(false);
    // ground truth: id → sketch, recorded by the inserters
    let truth: Mutex<Vec<(usize, BitVec)>> = Mutex::new(Vec::new());

    const INSERTERS: u64 = 4;
    const BATCH: usize = 8;
    // quick shape in the tier-1 gate; the scheduled soak lane sets
    // CABIN_SOAK=1 for a longer churn window
    let batches_per_inserter: usize =
        if std::env::var("CABIN_SOAK").ok().as_deref() == Some("1") {
            80
        } else {
            12
        };
    let total = INSERTERS as usize * batches_per_inserter * BATCH;

    std::thread::scope(|s| {
        // batched inserters
        for t in 0..INSERTERS {
            let store = &store;
            let truth = &truth;
            s.spawn(move || {
                let mut rng = Xoshiro256::new(1000 + t);
                for _ in 0..batches_per_inserter {
                    let batch: Vec<BitVec> = (0..BATCH).map(|_| sketch(&mut rng)).collect();
                    let ids = store.insert_batch(batch.clone());
                    let mut tr = truth.lock().unwrap();
                    tr.extend(ids.into_iter().zip(batch));
                    drop(tr);
                    std::thread::yield_now();
                }
            });
        }
        // query threads (one full-scan, one through the LSH indexes):
        // results must stay well-formed mid-churn
        for t in 0..2u64 {
            let store = &store;
            let done = &done;
            s.spawn(move || {
                let mut rng = Xoshiro256::new(2000 + t);
                let opts = if t == 0 {
                    QueryOpts::full_scan()
                } else {
                    QueryOpts::indexed(0, None)
                };
                while !done.load(Ordering::Relaxed) {
                    let q = sketch(&mut rng);
                    let hits = router::topk_with(store, &q, 5, &opts);
                    let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
                    for w in hits.windows(2) {
                        assert!(
                            w[0].dist <= w[1].dist || w[1].dist.is_nan(),
                            "unsorted hits: {hits:?}"
                        );
                    }
                    ids.sort_unstable();
                    ids.dedup();
                    assert_eq!(ids.len(), hits.len(), "duplicate hit ids: {hits:?}");
                }
            });
        }
        // distance-lookup thread: may race a half-placed batch (None) but
        // must never panic or return nonsense for settled ids
        {
            let store = &store;
            let done = &done;
            s.spawn(move || {
                let mut rng = Xoshiro256::new(3000);
                while !done.load(Ordering::Relaxed) {
                    let n = store.len();
                    if n >= 2 {
                        let a = rng.gen_range(n as u64) as usize;
                        let b = rng.gen_range(n as u64) as usize;
                        if let Some(d) = router::distance(store, a, b) {
                            assert!(d >= 0.0, "negative distance {d} for ({a},{b})");
                        }
                        if let Some(d) = router::distance(store, a, a) {
                            assert!(d.abs() < 1e-9, "self-distance {d} for id {a}");
                        }
                    }
                }
            });
        }
        // rebalance thread: periodically levels mid-insert
        {
            let store = &store;
            let done = &done;
            s.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    store.rebalance(2);
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            });
        }
        // inserter threads are the first INSERTERS spawns; rather than
        // track handles, poll until every insert has landed, then stop the
        // churn threads.
        while store.len() < total {
            std::thread::yield_now();
        }
        done.store(true, Ordering::Relaxed);
    });

    // no lost ids: dense, unique, fully retrievable
    assert_eq!(store.len(), total);
    let snap = store.snapshot_ordered();
    assert_eq!(snap.len(), total, "snapshot lost points");
    for (expect, (id, _)) in snap.iter().enumerate() {
        assert_eq!(*id, expect, "id gap at {expect}");
    }
    // every id still resolves (O(1) path) to exactly the inserted sketch
    let truth = truth.into_inner().unwrap();
    assert_eq!(truth.len(), total);
    for (id, expected) in &truth {
        assert_eq!(
            store.get(*id).as_ref(),
            Some(expected),
            "id {id} lost or corrupted"
        );
    }
    // a full-corpus query drops and duplicates nothing — on both paths
    // (indexed falls back per shard whenever candidates cannot cover k)
    let mut rng = Xoshiro256::new(42);
    let probe = sketch(&mut rng);
    for opts in [QueryOpts::full_scan(), QueryOpts::indexed(0, None)] {
        let hits = router::topk_with(&store, &probe, total, &opts);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..total).collect::<Vec<_>>());
    }
    // settled indexes mirror their arenas exactly
    for (rows, ix_len) in store.map_shards(|s| (s.ids.len(), s.index.as_ref().map(|ix| ix.len())))
    {
        assert_eq!(ix_len, Some(rows), "index desynced from arena");
    }
    // level shard sizes after a final rebalance
    store.rebalance(1);
    let sizes = store.shard_sizes();
    assert_eq!(sizes.iter().sum::<usize>(), total);
    let (max, min) = (
        *sizes.iter().max().unwrap() as i64,
        *sizes.iter().min().unwrap() as i64,
    );
    assert!(max - min <= 2, "shards not level after rebalance: {sizes:?}");
}
