//! Crash-recovery integration: snapshot + WAL persistence round-trips.
//!
//! The central invariant (the PR's acceptance bar): ingest a corpus,
//! hard-stop the store mid-stream (no graceful teardown — `mem::forget`
//! skips every Drop), recover a fresh store from the data dir, and
//! `get`/top-k/shard layout match the pre-crash store *exactly*, including
//! with the LSH index enabled (the indexes are deterministically
//! bulk-rebuilt over the recovered arenas).

use cabin::coordinator::client::Client;
use cabin::coordinator::router::{self, QueryOpts};
use cabin::coordinator::store::ShardedStore;
use cabin::coordinator::{Coordinator, CoordinatorConfig, ExecutorConfig, WriteOpts};
use cabin::index::{IndexConfig, IndexMode};
use cabin::persist::manifest::wal_path;
use cabin::persist::{Fingerprint, FsyncPolicy, PersistConfig, PersistCounters, PersistMode};
use cabin::sketch::{BitVec, SketchMatrix};
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::sync::Arc;

const DIM: usize = 256;

fn sketch(rng: &mut Xoshiro256) -> BitVec {
    BitVec::from_indices(DIM, rng.sample_indices(DIM, 40))
}

fn persist_cfg(dir: &TempDir, mode: PersistMode, snapshot_every: u64) -> PersistConfig {
    PersistConfig {
        mode,
        data_dir: Some(dir.path().to_path_buf()),
        fsync: FsyncPolicy::Never,
        snapshot_every,
        // synchronous commits: these tests pin the per-batch commit path
        // (the group-commit window is exercised by the wire test below
        // and the store/persist unit tests)
        commit_window_us: 0,
        wal_max_bytes: 0,
        compact_dead_frames: 0,
    }
}

fn fingerprint(num_shards: usize) -> Fingerprint {
    Fingerprint {
        sketch_dim: DIM,
        seed: 21,
        num_shards,
        input_dim: 2048,
        num_categories: 32,
    }
}

fn indexed_on() -> IndexConfig {
    IndexConfig {
        mode: IndexMode::On,
        ..Default::default()
    }
}

fn open(
    dir: &TempDir,
    mode: PersistMode,
    snapshot_every: u64,
    index: &IndexConfig,
) -> ShardedStore {
    let (store, _) = ShardedStore::open_durable(
        fingerprint(3),
        index,
        &persist_cfg(dir, mode, snapshot_every),
        Arc::new(PersistCounters::default()),
        &ExecutorConfig::default(),
    )
    .unwrap();
    store
}

/// Per-shard `(ids, arena)` image — `SketchMatrix` equality covers rows
/// *and* cached weights.
fn shard_image(store: &ShardedStore) -> Vec<(Vec<usize>, SketchMatrix)> {
    store.map_shards(|s| (s.ids.clone(), s.rows.clone()))
}

#[test]
fn hard_stop_recovery_matches_pre_crash_store_exactly() {
    let dir = TempDir::new("persist-hard-stop");
    let mut rng = Xoshiro256::new(1);
    // clustered corpus so the indexed path actually answers from buckets
    let centers: Vec<BitVec> = (0..6).map(|_| sketch(&mut rng)).collect();
    let mut corpus: Vec<BitVec> = Vec::new();
    for c in &centers {
        for _ in 0..15 {
            let mut p = c.clone();
            let flip = rng.gen_range(DIM as u64) as usize;
            if p.get(flip) {
                p.clear(flip);
            } else {
                p.set(flip);
            }
            corpus.push(p);
        }
    }
    let queries: Vec<BitVec> = (0..8).map(|_| sketch(&mut rng)).collect();

    let store = open(&dir, PersistMode::WalSnapshot, 0, &indexed_on());
    for chunk in corpus[..60].chunks(10) {
        store.insert_batch(chunk.to_vec());
    }
    store.rebalance(1);
    store.persist_snapshot().unwrap(); // generation 1: snapshot mid-stream
    for chunk in corpus[60..].chunks(10) {
        store.insert_batch(chunk.to_vec());
    }
    store.rebalance(1); // WAL-tail moves on top of the snapshot

    let pre_len = store.len();
    let pre_sizes = store.shard_sizes();
    let pre_image = shard_image(&store);
    let pre_snapshot = store.snapshot_ordered();
    let opts_indexed = QueryOpts::indexed(0, None);
    let opts_scan = QueryOpts::full_scan();
    let pre_topk: Vec<_> = queries
        .iter()
        .map(|q| {
            (
                router::topk_with(&store, q, 10, &opts_indexed),
                router::topk_with(&store, q, 10, &opts_scan),
            )
        })
        .collect();

    // hard stop: no Drop runs, nothing is flushed beyond the per-batch
    // commits the store already performed before "acknowledging"
    std::mem::forget(store);

    let recovered = open(&dir, PersistMode::WalSnapshot, 0, &indexed_on());
    assert_eq!(recovered.len(), pre_len);
    assert_eq!(recovered.shard_sizes(), pre_sizes);
    assert_eq!(shard_image(&recovered), pre_image, "ids/rows/weights differ");
    assert_eq!(recovered.snapshot_ordered(), pre_snapshot);
    for (id, expected) in &pre_snapshot {
        assert_eq!(recovered.get(*id).as_ref(), Some(expected), "id {id}");
    }
    // top-k identical pre/post — indexed and full-scan paths both
    for (q, (indexed, scan)) in queries.iter().zip(&pre_topk) {
        assert_eq!(&router::topk_with(&recovered, q, 10, &opts_indexed), indexed);
        assert_eq!(&router::topk_with(&recovered, q, 10, &opts_scan), scan);
    }
    // recovered LSH indexes mirror their arenas
    for (rows, ix_len) in
        recovered.map_shards(|s| (s.ids.len(), s.index.as_ref().map(|ix| ix.len())))
    {
        assert_eq!(ix_len, Some(rows));
    }
}

#[test]
fn rebalance_heavy_wal_replay_reproduces_exact_layout() {
    let dir = TempDir::new("persist-rebalance");
    let mut rng = Xoshiro256::new(2);
    let store = open(&dir, PersistMode::Wal, 0, &IndexConfig::default());
    // one big batch lands on a single shard, then rebalance scatters it:
    // recovery must replay the MoveOut/MoveIn pairs, not just inserts
    store.insert_batch((0..40).map(|_| sketch(&mut rng)).collect());
    assert!(store.rebalance(1) > 0);
    store.insert_batch((0..5).map(|_| sketch(&mut rng)).collect());
    let pre_image = shard_image(&store);
    let pre_sizes = store.shard_sizes();
    std::mem::forget(store);

    let recovered = open(&dir, PersistMode::Wal, 0, &IndexConfig::default());
    assert_eq!(recovered.shard_sizes(), pre_sizes);
    assert_eq!(shard_image(&recovered), pre_image);
}

#[test]
fn truncated_wal_tail_drops_only_the_partial_record() {
    let dir = TempDir::new("persist-torn");
    let mut rng = Xoshiro256::new(3);
    let pts: Vec<BitVec> = (0..7).map(|_| sketch(&mut rng)).collect();
    // single shard so the whole corpus shares one WAL file
    let open_one_shard = || {
        ShardedStore::open_durable(
            fingerprint(1),
            &IndexConfig::default(),
            &persist_cfg(&dir, PersistMode::Wal, 0),
            Arc::new(PersistCounters::default()),
            &ExecutorConfig::default(),
        )
        .unwrap()
    };
    {
        let (store, _) = open_one_shard();
        for p in &pts {
            store.insert_batch(vec![p.clone()]);
        }
    } // graceful drop: file fully flushed
    let wal = wal_path(dir.path(), 0, 0);
    let full = std::fs::metadata(&wal).unwrap().len();
    std::fs::OpenOptions::new()
        .write(true)
        .open(&wal)
        .unwrap()
        .set_len(full - 9) // tear the last frame mid-payload
        .unwrap();

    let (recovered, report) = open_one_shard();
    assert_eq!(report.truncated_tails, 1);
    assert_eq!(report.replayed_records, 6);
    assert_eq!(recovered.len(), 6, "only the torn final record is lost");
    for (i, p) in pts[..6].iter().enumerate() {
        assert_eq!(recovered.get(i).as_ref(), Some(p), "id {i}");
    }
    assert!(recovered.get(6).is_none());
    // the store keeps appending cleanly past the repaired boundary
    let ids = recovered.insert_batch(vec![pts[6].clone()]);
    assert_eq!(ids, vec![6]);
    std::mem::forget(recovered);
    let (again, report) = open_one_shard();
    assert_eq!(report.truncated_tails, 0, "tail was repaired on first recovery");
    assert_eq!(again.len(), 7);
    assert_eq!(again.get(6).as_ref(), Some(&pts[6]));
}

#[test]
fn wire_level_restart_serves_the_recovered_corpus() {
    use cabin::data::{synth::SynthSpec, CatVector};

    let dir = TempDir::new("persist-wire");
    let mut spec = SynthSpec::small_demo();
    spec.dim = 600;
    spec.num_categories = 16;
    spec.num_points = 24;
    let pts: Vec<CatVector> = spec.generate(4).points;

    let config = || CoordinatorConfig {
        input_dim: 600,
        num_categories: 16,
        sketch_dim: 128,
        seed: 5,
        num_shards: 2,
        use_xla: false,
        persist: PersistConfig {
            mode: PersistMode::WalSnapshot,
            data_dir: Some(dir.path().to_path_buf()),
            // fsync=always + a window: exercise the group-commit ack path
            // over the wire (group commit only engages under `always`)
            fsync: FsyncPolicy::Always,
            snapshot_every: 0,
            commit_window_us: 1_000,
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        },
        ..Default::default()
    };
    let serve = |config: CoordinatorConfig| {
        let coordinator = Arc::new(Coordinator::try_new(config).unwrap());
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let server = Arc::clone(&coordinator);
        let handle = std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", |addr| {
                    let _ = tx.send(addr);
                })
                .unwrap();
        });
        (rx.recv().unwrap(), handle)
    };

    // first life: ingest, snapshot mid-stream, flush, graceful shutdown
    let (ids, pre_hits) = {
        let (addr, server) = serve(config());
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let mut ids = Vec::new();
        for p in &pts[..12] {
            ids.push(c.insert(p.clone()).unwrap());
        }
        assert_eq!(c.snapshot().unwrap(), 1);
        for p in &pts[12..] {
            ids.push(c.insert(p.clone()).unwrap());
        }
        c.flush().unwrap();
        let hits = c.query(pts[7].clone(), 5).unwrap();
        assert_eq!(hits[0].id, ids[7]);
        c.shutdown().unwrap();
        server.join().unwrap();
        (ids, hits)
    };

    // second life: same data dir, corpus is back and identically ranked
    let (addr, server) = serve(config());
    let mut c = Client::connect(&addr.to_string()).unwrap();
    assert_eq!(c.query(pts[7].clone(), 5).unwrap(), pre_hits);
    let d = c.distance(ids[0], ids[23]).unwrap();
    assert!(d.is_finite());
    assert_eq!(c.distance(ids[23], ids[23]).unwrap(), 0.0);
    assert_eq!(c.stat("persist_generation").unwrap(), 1.0);
    assert!(c.stat("persist_recovery_ms").unwrap() >= 0.0);
    // snapshot works in the second life too and bumps the generation
    assert_eq!(c.snapshot().unwrap(), 2);
    c.shutdown().unwrap();
    server.join().unwrap();
}

/// The acceptance bar for WAL compaction: recovering *after* a rotation
/// folded the dead frames away must produce the same corpus as
/// recovering *before* it, when the mixed mutation stream was replayed
/// record by record. Three lives of one data dir: write a mixed stream
/// (life 1), recover by replay and capture what the service answers
/// (life 2, pre-compaction), fold with `snapshot`, recover from the
/// folded generation and require identical answers (life 3).
#[test]
fn compaction_rotation_preserves_recovery_exactly() {
    use cabin::data::{synth::SynthSpec, CatVector};

    let dir = TempDir::new("persist-compact-wire");
    let mut spec = SynthSpec::small_demo();
    spec.dim = 600;
    spec.num_categories = 16;
    spec.num_points = 26;
    let pts: Vec<CatVector> = spec.generate(9).points;

    let config = || CoordinatorConfig {
        input_dim: 600,
        num_categories: 16,
        sketch_dim: 128,
        seed: 5,
        num_shards: 2,
        use_xla: false,
        persist: PersistConfig {
            mode: PersistMode::WalSnapshot,
            data_dir: Some(dir.path().to_path_buf()),
            fsync: FsyncPolicy::Never,
            snapshot_every: 0,
            commit_window_us: 0,
            wal_max_bytes: 0,
            compact_dead_frames: 0, // manual `snapshot` op is the fold
        },
        ..Default::default()
    };
    let serve = |config: CoordinatorConfig| {
        let coordinator = Arc::new(Coordinator::try_new(config).unwrap());
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let server = Arc::clone(&coordinator);
        let handle = std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", |addr| {
                    let _ = tx.send(addr);
                })
                .unwrap();
        });
        (rx.recv().unwrap(), handle)
    };
    let probes = || pts[..6].to_vec();

    // life 1: a mixed mutation stream, all of it living only in the WAL
    let ids = {
        let (addr, server) = serve(config());
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let mut ids = Vec::new();
        for p in &pts[..20] {
            ids.push(c.insert(p.clone()).unwrap());
        }
        c.delete(ids[3]).unwrap();
        c.delete(ids[11]).unwrap();
        c.upsert_with(ids[7], pts[20].clone(), &WriteOpts::default()).unwrap();
        c.upsert_with(ids[15], pts[21].clone(), &WriteOpts::default()).unwrap();
        for p in &pts[22..24] {
            ids.push(c.insert(p.clone()).unwrap());
        }
        c.flush().unwrap();
        c.shutdown().unwrap();
        server.join().unwrap();
        ids
    };

    // life 2: pre-compaction recovery — replays insert/delete/upsert
    // frames one by one. Capture the service's answers, then fold.
    let (pre_hits, pre_up) = {
        let (addr, server) = serve(config());
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(c.stat("persist_generation").unwrap(), 0.0);
        let hits = c.query_batch(probes(), 5).unwrap();
        let up = c.query(pts[20].clone(), 1).unwrap();
        assert_eq!(up[0].id, ids[7], "upsert replayed into place");
        assert!(c.distance(ids[3], ids[0]).is_err(), "deleted id stays gone");
        assert_eq!(c.snapshot().unwrap(), 1); // the fold
        c.shutdown().unwrap();
        server.join().unwrap();
        (hits, up)
    };

    // life 3: post-compaction recovery — loads the folded snapshot (the
    // dead frames are gone) and must answer identically.
    let (addr, server) = serve(config());
    let mut c = Client::connect(&addr.to_string()).unwrap();
    assert_eq!(c.stat("persist_generation").unwrap(), 1.0);
    assert_eq!(c.query_batch(probes(), 5).unwrap(), pre_hits);
    assert_eq!(c.query(pts[20].clone(), 1).unwrap(), pre_up);
    assert!(c.distance(ids[3], ids[0]).is_err());
    assert!(c.distance(ids[11], ids[0]).is_err());
    assert_eq!(c.distance(ids[7], ids[7]).unwrap(), 0.0);
    // writes keep flowing on the folded generation
    let next = c.insert(pts[24].clone()).unwrap();
    assert!(next > ids[21]);
    c.shutdown().unwrap();
    server.join().unwrap();
}
