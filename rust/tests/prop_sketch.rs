//! Property tests over the sketch pipeline invariants (substrate:
//! `cabin::testing::PropRunner` — proptest is unavailable offline).

use cabin::data::CatVector;
use cabin::sketch::{cham, BinEm, BinSketch, BitVec, CabinSketcher, PsiMode, SketchConfig};
use cabin::testing::PropRunner;

fn random_cat(rng: &mut cabin::util::rng::Xoshiro256, size: usize) -> (CatVector, usize, u16) {
    let dim = 50 + size * 10;
    let c = 1 + rng.gen_range(30) as u16;
    let nnz = rng.gen_range((dim / 2) as u64) as usize;
    (CatVector::random(dim, nnz, c, rng), dim, c)
}

#[test]
fn prop_cabin_weight_bounded_by_nnz() {
    // |Cabin(u)|₁ ≤ nnz(u): OR-folding and ψ-masking can only lose ones.
    PropRunner::new("cabin weight ≤ nnz", 128).run(|rng, size| {
        let (u, dim, c) = random_cat(rng, size);
        let d = 8 + rng.gen_range(512) as usize;
        let sk = CabinSketcher::new(dim, c, d, rng.next_u64());
        let s = sk.sketch(&u);
        if s.count_ones() <= u.nnz() {
            Ok(())
        } else {
            Err(format!("weight {} > nnz {}", s.count_ones(), u.nnz()))
        }
    });
}

#[test]
fn prop_binem_zero_preservation() {
    // BinEm never sets a bit where the input is missing (Lemma 1a).
    PropRunner::new("binem zero preservation", 96).run(|rng, size| {
        let (u, dim, c) = random_cat(rng, size);
        for mode in [PsiMode::Shared, PsiMode::PerAttribute] {
            let be = BinEm::new(dim, c, mode, rng.next_u64());
            let enc = be.encode(&u);
            for i in enc.iter_ones() {
                if u.get(i) == 0 {
                    return Err(format!("{mode:?}: bit {i} set on missing attr"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_equal_inputs_equal_sketches() {
    PropRunner::new("determinism", 64).run(|rng, size| {
        let (u, dim, c) = random_cat(rng, size);
        let seed = rng.next_u64();
        let a = CabinSketcher::new(dim, c, 64, seed).sketch(&u);
        let b = CabinSketcher::new(dim, c, 64, seed).sketch(&u);
        if a == b {
            Ok(())
        } else {
            Err("same seed, different sketch".into())
        }
    });
}

#[test]
fn prop_fused_equals_staged() {
    PropRunner::new("fused == staged", 96).run(|rng, size| {
        let (u, dim, c) = random_cat(rng, size);
        let sk = CabinSketcher::new(dim, c, 32 + size, rng.next_u64());
        let fused = sk.sketch(&u);
        let (_, staged) = sk.sketch_staged(&u);
        if fused == staged {
            Ok(())
        } else {
            Err("fused != staged".into())
        }
    });
}

#[test]
fn prop_estimator_symmetry_and_identity() {
    PropRunner::new("cham symmetry/identity", 96).run(|rng, size| {
        let d = 64 + size;
        let na = rng.gen_range(d as u64 / 2) as usize;
        let nb = rng.gen_range(d as u64 / 2) as usize;
        let a = BitVec::from_indices(d, rng.sample_indices(d, na));
        let b = BitVec::from_indices(d, rng.sample_indices(d, nb));
        let ab = cham::binhamming_occupancy(&a, &b);
        let ba = cham::binhamming_occupancy(&b, &a);
        if (ab - ba).abs() > 1e-9 {
            return Err(format!("asymmetric: {ab} vs {ba}"));
        }
        if cham::binhamming_occupancy(&a, &a) != 0.0 {
            return Err("self-distance nonzero".into());
        }
        if !ab.is_finite() || ab < 0.0 {
            return Err(format!("invalid estimate {ab}"));
        }
        Ok(())
    });
}

#[test]
fn prop_estimator_monotone_in_inner_product() {
    // Fixing weights, the estimate decreases as ⟨ũ,ṽ⟩ grows.
    PropRunner::new("estimator monotonicity", 64).run(|rng, size| {
        let d = 256 + size;
        let wu = 10.0 + rng.gen_range(60) as f64;
        let wv = 10.0 + rng.gen_range(60) as f64;
        let max_ip = wu.min(wv);
        let mut last = f64::INFINITY;
        let mut ip = 0.0;
        while ip <= max_ip {
            let h = cham::binhamming_from_stats(wu, wv, ip, d);
            if h > last + 1e-9 {
                return Err(format!("not monotone at ip={ip}: {h} > {last}"));
            }
            last = h;
            ip += 1.0;
        }
        Ok(())
    });
}

#[test]
fn prop_binsketch_or_homomorphism() {
    // sketch(u ∨ v) == sketch(u) ∨ sketch(v) — OR-folding commutes with OR.
    PropRunner::new("binsketch OR homomorphism", 96).run(|rng, size| {
        let n = 100 + size * 4;
        let d = 16 + size / 2;
        let bs = BinSketch::new(n, d, rng.next_u64());
        let u = BitVec::from_indices(n, rng.sample_indices(n, n / 10));
        let v = BitVec::from_indices(n, rng.sample_indices(n, n / 10));
        let mut uv = u.clone();
        uv.or_assign(&v);
        let mut lhs = bs.compress(&u);
        lhs.or_assign(&bs.compress(&v));
        if bs.compress(&uv) == lhs {
            Ok(())
        } else {
            Err("OR homomorphism violated".into())
        }
    });
}

#[test]
fn prop_lemma2_expectation_statistical() {
    // Averaged over ψ seeds, 2·HD(u',v') tracks HD(u,v) within 4σ.
    PropRunner::new("lemma2 expectation", 12).run(|rng, _size| {
        let dim = 3000;
        let c = 16;
        let u = CatVector::random(dim, 200, c, rng);
        let v = CatVector::random(dim, 200, c, rng);
        let truth = u.hamming(&v) as f64;
        let trials = 200;
        let mut total = 0.0;
        for s in 0..trials {
            let be = BinEm::new(dim, c, PsiMode::PerAttribute, rng.next_u64() ^ s);
            total += 2.0 * be.encode(&u).xor_count(&be.encode(&v)) as f64;
        }
        let mean = total / trials as f64;
        // Var(2·HD') = 4·h/4 = h per trial ⇒ σ_mean = sqrt(h/trials)
        let sigma = (truth / trials as f64).sqrt().max(1e-9);
        if (mean - truth).abs() < 4.0 * sigma * 2.0 + 2.0 {
            Ok(())
        } else {
            Err(format!("mean {mean} truth {truth} σ {sigma}"))
        }
    });
}

#[test]
fn prop_cham_theorem2_bound_statistical() {
    // |Cham − HD| ≤ 11·sqrt(s·ln(7/δ)) with δ=0.05 must hold in the vast
    // majority of cases; allow isolated near-boundary failures by testing
    // the 95th percentile behaviour across cases.
    let mut violations = 0;
    let cases = 60;
    let mut rng = cabin::util::rng::Xoshiro256::new(0xCAB2);
    for case in 0..cases {
        let dim = 10_000;
        let c = 32;
        let s = 150;
        let u = CatVector::random(dim, s, c, &mut rng);
        let v = CatVector::random(dim, s, c, &mut rng);
        let cfg = SketchConfig::new(dim, c, 2048, case as u64);
        let sk = CabinSketcher::from_config(cfg);
        let est = cham::estimate_hamming(&sk.sketch(&u), &sk.sketch(&v), sk.config());
        let truth = u.hamming(&v) as f64;
        let bound = 11.0 * ((s as f64) * (7.0f64 / 0.05).ln()).sqrt();
        if (est - truth).abs() > bound {
            violations += 1;
        }
    }
    assert!(
        violations <= 3,
        "Theorem 2 bound violated in {violations}/{cases} cases"
    );
}
