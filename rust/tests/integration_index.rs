//! Wire-protocol coverage for the LSH index subsystem: queries through a
//! real TCP coordinator with the index on, off, and racing a store
//! rebalance (whose row moves are mirrored into the per-shard indexes
//! under their write locks — responses must stay well-formed throughout).

use cabin::coordinator::client::Client;
use cabin::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, IndexConfig, IndexMode,
};
use cabin::data::{synth::SynthSpec, CatVector};
use cabin::sketch::BitVec;
use cabin::util::rng::Xoshiro256;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

const DIM: usize = 700;
const SKETCH_DIM: usize = 256;

fn start_server(
    mode: IndexMode,
) -> (
    std::net::SocketAddr,
    std::thread::JoinHandle<()>,
    Arc<Coordinator>,
) {
    let config = CoordinatorConfig {
        input_dim: DIM,
        num_categories: 16,
        sketch_dim: SKETCH_DIM,
        seed: 5,
        num_shards: 3,
        batcher: BatcherConfig {
            max_batch: 16,
            max_delay: Duration::from_millis(1),
            queue_cap: 512,
        },
        use_xla: false,
        heatmap_limit: 128,
        index: IndexConfig {
            mode,
            ..Default::default()
        },
        persist: Default::default(),
        ..Default::default()
    };
    let coordinator = Arc::new(Coordinator::new(config));
    let (tx, rx) = std::sync::mpsc::sync_channel(1);
    let server = Arc::clone(&coordinator);
    let handle = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", |addr| {
                let _ = tx.send(addr);
            })
            .unwrap();
    });
    (rx.recv().unwrap(), handle, coordinator)
}

fn twin(n: usize, seed: u64) -> Vec<CatVector> {
    let mut spec = SynthSpec::small_demo();
    spec.dim = DIM;
    spec.num_categories = 16;
    spec.num_points = n;
    spec.generate(seed).points
}

#[test]
fn index_on_over_the_wire() {
    let (addr, server, coordinator) = start_server(IndexMode::On);
    let pts = twin(40, 1);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let mut ids = Vec::new();
    for p in &pts {
        ids.push(c.insert(p.clone()).unwrap());
    }

    // an inserted vector sketches identically, collides in every band,
    // and must come back as its own nearest hit
    for qi in [0usize, 7, 19, 33] {
        let hits = c.query(pts[qi].clone(), 3).unwrap();
        assert_eq!(hits.len(), 3, "query {qi}: {hits:?}");
        assert_eq!(hits[0].id, ids[qi], "query {qi}: {hits:?}");
        assert!(hits[0].dist < 1e-9, "query {qi}: {hits:?}");
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist, "unsorted: {hits:?}");
        }
    }

    // the batched path shares the indexed scan
    let results = c.query_batch(pts[..4].to_vec(), 3).unwrap();
    for (qi, hits) in results.iter().enumerate() {
        assert_eq!(hits[0].id, ids[qi], "batch query {qi}: {hits:?}");
    }

    // traffic counters: every shard scan of every query went through the
    // index path (mode = On ⇒ indexed_scans + fallbacks covers them all).
    // One stats round-trip = one consistent snapshot for the sums below.
    let queries = 4 + 4; // single + batched
    let shards = coordinator.store.num_shards() as f64;
    let snap = c.stats().unwrap();
    let get = |k: &str| {
        cabin::coordinator::stats_field(&snap, k)
            .unwrap_or_else(|| panic!("stats field '{k}' missing"))
    };
    assert!(get("index_probes") > 0.0);
    assert_eq!(
        get("index_indexed_scans") + get("index_fallbacks"),
        queries as f64 * shards
    );
    assert_eq!(get("index_cfg_mode"), 2.0); // On
    // candidates generated and reranked are consistent
    assert!(get("index_reranked") <= get("index_candidates"));

    c.shutdown().unwrap();
    server.join().unwrap();
}

#[test]
fn index_off_never_touches_the_index_path() {
    let (addr, server, _coordinator) = start_server(IndexMode::Off);
    let pts = twin(25, 2);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let mut ids = Vec::new();
    for p in &pts {
        ids.push(c.insert(p.clone()).unwrap());
    }
    let hits = c.query(pts[6].clone(), 4).unwrap();
    assert_eq!(hits.len(), 4);
    assert_eq!(hits[0].id, ids[6]);
    assert!(hits[0].dist < 1e-9);
    // off ⇒ zero index traffic, and the config advertises it (one
    // snapshot, one round trip)
    let snap = c.stats().unwrap();
    let get = |k: &str| {
        cabin::coordinator::stats_field(&snap, k)
            .unwrap_or_else(|| panic!("stats field '{k}' missing"))
    };
    assert_eq!(get("index_probes"), 0.0);
    assert_eq!(get("index_indexed_scans"), 0.0);
    assert_eq!(get("index_fallbacks"), 0.0);
    assert_eq!(get("index_cfg_mode"), 0.0); // Off
    c.shutdown().unwrap();
    server.join().unwrap();
}

#[test]
fn indexed_queries_stay_wellformed_mid_rebalance() {
    let (addr, server, coordinator) = start_server(IndexMode::On);
    let pts = twin(30, 3);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let mut ids = Vec::new();
    for p in &pts {
        ids.push(c.insert(p.clone()).unwrap());
    }

    // churn thread: repeatedly unbalance the store with big direct batches
    // (a whole batch lands on one shard) and rebalance it back — every
    // rebalance move updates both affected shard indexes in place
    let done = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|s| {
        let store = &coordinator.store;
        let done_ref = &done;
        s.spawn(move || {
            let mut rng = Xoshiro256::new(99);
            // bounded churn: enough rounds to overlap all queries, small
            // enough that the corpus (and thus query time) stays bounded
            for round in 0..200 {
                if done_ref.load(Ordering::Relaxed) {
                    break;
                }
                let filler: Vec<BitVec> = (0..60)
                    .map(|_| {
                        BitVec::from_indices(SKETCH_DIM, rng.sample_indices(SKETCH_DIM, 40))
                    })
                    .collect();
                store.insert_batch(filler);
                let _ = store.rebalance(1);
                if round % 8 == 0 {
                    std::thread::yield_now();
                }
            }
        });

        // query under churn: responses must stay well-formed (k hits,
        // ascending, no duplicate ids) even while indexes are rebuilt
        let mut qc = Client::connect(&addr.to_string()).unwrap();
        for round in 0..40 {
            let qi = round % pts.len();
            let hits = qc.query(pts[qi].clone(), 5).unwrap();
            assert!(hits.len() <= 5);
            for w in hits.windows(2) {
                assert!(
                    w[0].dist <= w[1].dist || w[1].dist.is_nan(),
                    "unsorted mid-rebalance: {hits:?}"
                );
            }
            let mut seen: Vec<usize> = hits.iter().map(|h| h.id).collect();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), hits.len(), "duplicate ids: {hits:?}");
        }
        done.store(true, Ordering::Relaxed);
    });

    // settled state: every original point is again its own nearest hit
    // through the maintained indexes
    coordinator.store.rebalance(1);
    for qi in [0usize, 11, 29] {
        let hits = c.query(pts[qi].clone(), 1).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, ids[qi], "query {qi} after churn: {hits:?}");
        assert!(hits[0].dist < 1e-9);
    }
    c.shutdown().unwrap();
    server.join().unwrap();
}
