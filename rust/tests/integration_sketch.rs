//! End-to-end sketch accuracy on the Table 1 dataset twins: Theorem-2-level
//! estimation error, sparsity preservation (Lemma 4), memory claims.

use cabin::baselines::by_key;
use cabin::data::registry::DatasetSpec;
use cabin::sketch::{cham, CabinSketcher, SketchConfig};

#[test]
fn cham_accuracy_on_kos_twin() {
    let spec = DatasetSpec::by_key("kos").unwrap();
    let ds = spec.synth_spec(60).generate(42);
    let s = ds.max_density();
    let d = 2048;
    let sk = CabinSketcher::from_config(SketchConfig::new(ds.dim(), ds.num_categories(), d, 7));
    let sketches = sk.sketch_dataset(&ds, 4);
    let bound = 11.0 * ((s as f64) * (7.0f64 / 0.05).ln()).sqrt();
    let mut violations = 0;
    let mut pairs = 0;
    for i in 0..ds.len() {
        for j in (i + 1)..ds.len() {
            let truth = ds.points[i].hamming(&ds.points[j]) as f64;
            let est = cham::estimate_hamming(&sketches[i], &sketches[j], sk.config());
            pairs += 1;
            if (est - truth).abs() > bound {
                violations += 1;
            }
        }
    }
    assert!(
        (violations as f64) < 0.05 * pairs as f64,
        "{violations}/{pairs} pairs violate the Theorem-2 bound {bound:.1}"
    );
}

#[test]
fn sparsity_preserved_lemma4_on_all_twins() {
    for key in ["kos", "nips", "pubmed"] {
        let spec = DatasetSpec::by_key(key).unwrap();
        let ds = spec.synth_spec(40).generate(11);
        let sk =
            CabinSketcher::from_config(SketchConfig::new(ds.dim(), ds.num_categories(), 1024, 3));
        for p in &ds.points {
            let s = sk.sketch(p);
            assert!(
                s.count_ones() <= p.nnz(),
                "{key}: sketch weight {} > nnz {}",
                s.count_ones(),
                p.nnz()
            );
        }
    }
}

#[test]
fn sketch_memory_beats_dense_representation() {
    // Section 1's space argument: d-bit sketches vs n×f32.
    let spec = DatasetSpec::by_key("nytimes").unwrap();
    let ds = spec.synth_spec(20).generate(5);
    let sk = CabinSketcher::from_config(SketchConfig::new(ds.dim(), ds.num_categories(), 1000, 1));
    let sketch_bytes = sk.sketch(&ds.points[0]).memory_bytes();
    let dense_f32_bytes = ds.dim() * 4;
    assert!(sketch_bytes * 1000 < dense_f32_bytes, "{sketch_bytes} vs {dense_f32_bytes}");
    // and 32x vs a real-valued sketch of the same dimension
    assert!(sketch_bytes <= 1000 / 8 + 8);
}

#[test]
fn rmse_improves_with_dimension_on_enron_twin() {
    let spec = DatasetSpec::by_key("enron").unwrap();
    let ds = spec.synth_spec(50).generate(9);
    let r = by_key("cabin").unwrap();
    let e_small = cabin::analysis::rmse::rmse(&ds, &r.reduce(&ds, 128, 3));
    let e_mid = cabin::analysis::rmse::rmse(&ds, &r.reduce(&ds, 512, 3));
    let e_large = cabin::analysis::rmse::rmse(&ds, &r.reduce(&ds, 2048, 3));
    assert!(e_large < e_mid && e_mid < e_small, "{e_small} {e_mid} {e_large}");
}

#[test]
fn figure3_shape_cabin_best_discrete_method_at_moderate_dim() {
    let spec = DatasetSpec::by_key("kos").unwrap();
    let ds = spec.synth_spec(50).generate(21);
    let d = 512;
    let cabin_rmse = cabin::analysis::rmse::rmse(&ds, &by_key("cabin").unwrap().reduce(&ds, d, 5));
    for other in ["hlsh", "sh", "kt"] {
        let r = cabin::analysis::rmse::rmse(&ds, &by_key(other).unwrap().reduce(&ds, d, 5));
        assert!(
            cabin_rmse < r,
            "cabin {cabin_rmse} !< {other} {r} at d={d}"
        );
    }
}
