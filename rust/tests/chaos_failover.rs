//! Chaos failover lanes: two real processes, deterministic fault
//! injection, and the epoch-fencing contract under partitions, torn
//! transfers and `kill -9`.
//!
//! Every test here is gated on `CABIN_CHAOS=1` (the scheduled CI chaos
//! lane sets it; `cargo test` tier-1 skips them in milliseconds) because
//! each one spawns the release binary, drives real TCP, and waits on
//! real probe timers.
//!
//! Fault injection uses [`cabin::fault`]'s external arming paths:
//! `CABIN_FAILPOINTS` (fixed for the child's lifetime — torn transfers,
//! slow sockets) and `CABIN_FAILPOINTS_FILE` (re-read on change — the
//! partition/heal lever: rewriting the file partitions a *running*
//! primary, truncating it heals).
//!
//! The scenarios:
//!
//! 1. **Split brain**: partition a primary under an `--auto-promote`
//!    follower; the follower self-promotes at a bumped epoch; the healed
//!    old primary fences itself on the first epoch-gossiping contact and
//!    rejoins as a follower. Two writable primaries never both ack.
//! 2. **Torn transfer**: inject shipper failures mid-snapshot and
//!    mid-tail; the follower retries through them to bit-identical
//!    convergence.
//! 3. **Slow ≠ dead**: a primary answering within the probe budget —
//!    slowly — is never promoted over.
//! 4. **Kill -9 + auto-promote**: hard-kill the primary; the follower
//!    self-promotes losing no acknowledged insert.
//!
//! Failover timelines are additionally asserted from each node's
//! flight-recorder journal (the `events` wire op): probe failures must
//! hold strictly smaller journal seqs than the promotion they caused,
//! and a fenced ex-primary's journal holds its `fence_raised` event.

use cabin::coordinator::client::{Client, MultiClient};
use cabin::data::CatVector;
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const DIM: usize = 400;
const SHARDS: usize = 2;

fn chaos_enabled() -> bool {
    if std::env::var("CABIN_CHAOS").ok().as_deref() == Some("1") {
        return true;
    }
    eprintln!("chaos lane skipped (set CABIN_CHAOS=1 to run)");
    false
}

/// Kills the child on drop so a failing assert can't leak a server.
struct ServerProc {
    child: Child,
    pub addr: String,
}

impl ServerProc {
    /// Spawn the real binary with the pinned corpus shape, extra args,
    /// and extra environment (the failpoint arming channel).
    fn spawn(
        data_dir: &std::path::Path,
        extra_args: &[&str],
        envs: &[(&str, &str)],
    ) -> ServerProc {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_cabin"));
        cmd.args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--dim",
            "400",
            "--categories",
            "8",
            "--sketch-dim",
            "128",
            "--seed",
            "3",
            "--shards",
            "2",
            "--no-xla=true",
            "--max-delay-ms",
            "1",
            "--fsync",
            "never",
        ])
        .args(extra_args)
        .arg("--data-dir")
        .arg(data_dir)
        .stdout(Stdio::piped())
        .stderr(Stdio::null());
        for (k, v) in envs {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().expect("spawn cabin serve");
        let stdout = child.stdout.take().expect("child stdout");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            let line = lines
                .next()
                .expect("server exited before binding")
                .expect("read server stdout");
            if let Some(bound) = line.strip_prefix("[serve] bound ") {
                break bound.trim().to_string();
            }
        };
        std::thread::spawn(move || for _ in lines {});
        ServerProc { child, addr }
    }

    /// Hard stop: SIGKILL, no shutdown request, no flush.
    fn kill9(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        self.kill9();
    }
}

/// Aggressive-but-realistic probe settings: dead in ~300 ms, while a
/// probe answering inside 2 s still counts as healthy.
const AUTO_PROMOTE: &[&str] = &[
    "--auto-promote",
    "--probe-interval-ms",
    "100",
    "--probe-timeout-ms",
    "2000",
    "--probe-failures",
    "3",
];

fn vectors(seed: u64, n: usize) -> Vec<CatVector> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| CatVector::random(DIM, 50, 8, &mut rng)).collect()
}

fn ingest(c: &mut Client, pts: &[CatVector]) -> Vec<(usize, CatVector)> {
    pts.iter()
        .map(|v| (c.insert(v.clone()).expect("insert"), v.clone()))
        .collect()
}

fn assert_serves_exactly(c: &mut Client, acked: &[(usize, CatVector)]) {
    for (id, v) in acked {
        let hits = c.query(v.clone(), 1).expect("query");
        assert_eq!(hits[0].id, *id, "id {id} lost");
        assert!(hits[0].dist < 1e-9, "id {id} corrupted (dist {})", hits[0].dist);
    }
}

/// Journal timeline helper: the `seq` of the first event named `event`
/// in a `Client::events` dump, if any. Each server process has its own
/// journal, so chaos timelines are deterministic per node.
fn event_seq(dump: &str, event: &str) -> Option<u64> {
    let needle = format!("\"event\":\"{event}\"");
    dump.lines().find(|l| l.contains(&needle)).and_then(|l| {
        let obj = cabin::util::json::parse(l).ok()?;
        obj.get("seq")?.as_f64().map(|v| v as u64)
    })
}

/// Poll one stats field until `pred` holds (chaos-scale 60 s deadline).
fn wait_stat(c: &mut Client, field: &str, pred: impl Fn(f64) -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let v = c.stat(field).unwrap_or(f64::NAN);
        if pred(v) {
            return;
        }
        assert!(Instant::now() < deadline, "{what}: {field} stuck at {v}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Poll both processes until their per-shard durable seq horizons agree.
fn wait_parity(a: &mut Client, b: &mut Client) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let equal = (0..SHARDS).all(|si| {
            let field = format!("persist_next_seq_shard{si}");
            a.stat(&field).unwrap() == b.stat(&field).unwrap()
        });
        if equal {
            return;
        }
        assert!(Instant::now() < deadline, "seq parity never reached");
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn split_brain_partition_promotes_fences_and_rejoins() {
    if !chaos_enabled() {
        return;
    }
    let dir_p = TempDir::new("chaos-split-primary");
    let dir_f = TempDir::new("chaos-split-follower");
    // the partition lever: a failpoint file the test rewrites while the
    // primary runs
    let fp_file = dir_p.path().join("failpoints.txt");
    std::fs::write(&fp_file, "").unwrap();
    let mut primary = ServerProc::spawn(
        dir_p.path(),
        &[],
        &[("CABIN_FAILPOINTS_FILE", fp_file.to_str().unwrap())],
    );
    let mut pc = Client::connect(&primary.addr).expect("connect primary");
    let pts = vectors(11, 24);
    let mut acked = ingest(&mut pc, &pts);

    let mut follower_args = vec!["--replicate-from", primary.addr.as_str()];
    follower_args.extend_from_slice(AUTO_PROMOTE);
    let follower = ServerProc::spawn(dir_f.path(), &follower_args, &[]);
    let mut fc = Client::connect(&follower.addr).expect("connect follower");
    wait_parity(&mut pc, &mut fc);
    assert_eq!(fc.stat("repl_role").unwrap(), 1.0);

    // the resilient client follows the replica's write redirect to the
    // primary and learns the epoch from the ack
    let mut mc = MultiClient::new(&follower.addr, &[]);
    let extra = vectors(12, 2);
    for v in &extra {
        acked.push((mc.insert(v.clone()).expect("redirected insert"), v.clone()));
    }
    assert_eq!(mc.primary(), primary.addr, "redirect must re-aim the client");
    assert_eq!(mc.last_epoch(), 1, "acks carry the primary's epoch");
    wait_parity(&mut pc, &mut fc);

    // PARTITION: the primary refuses new connections and tears existing
    // ones — dead from the prober's point of view
    std::fs::write(&fp_file, "accept=err\nconn_read=err\n").unwrap();
    wait_stat(&mut fc, "repl_role", |v| v == 2.0, "auto-promote after partition");
    assert_eq!(fc.stat("repl_epoch").unwrap(), 2.0, "promotion bumps the epoch");
    assert_eq!(fc.stat("failover_promotions").unwrap(), 1.0);
    assert!(fc.stat("failover_probe_failures").unwrap() >= 3.0);

    // FLIGHT RECORDER: the promoted follower's journal tells the story
    // in causal order — probes failed strictly before the auto-promote
    let dump = fc.events().expect("events dump");
    let first_fail =
        event_seq(&dump, "probe_failed").expect("probe_failed missing from journal");
    let promoted =
        event_seq(&dump, "auto_promoted").expect("auto_promoted missing from journal");
    assert!(
        first_fail < promoted,
        "journal out of order: probe_failed seq {first_fail} !< auto_promoted seq {promoted}"
    );

    // the new primary acks writes, continuing the id line
    let next = vectors(13, 3);
    for v in &next {
        acked.push((fc.insert(v.clone()).expect("insert on new primary"), v.clone()));
    }
    assert_eq!(acked.last().unwrap().0, acked.len() - 1, "id line continued");

    // HEAL. The old primary revives un-fenced — until the first contact
    // carrying the newer epoch, after which it must reject every write,
    // durably, across its own restarts.
    std::fs::write(&fp_file, "").unwrap();
    let mut pc2 = Client::connect(&primary.addr).expect("reconnect old primary");
    assert_eq!(pc2.ping_epoch(Some(2)).expect("gossip ping"), Some(1));
    let err = pc2.insert(pts[0].clone()).unwrap_err().to_string();
    assert!(err.contains("fenced"), "{err}");
    assert!(err.contains("epoch 2"), "{err}");
    assert_eq!(pc2.stat("failover_fenced").unwrap(), 2.0);
    assert_eq!(pc2.stat("failover_fence_events").unwrap(), 1.0);
    // and its own flight recorder holds the fence event for post-mortems
    let dump = pc2.events().expect("events dump");
    assert!(
        event_seq(&dump, "fence_raised").is_some(),
        "fence_raised missing from the old primary's journal:\n{dump}"
    );

    // REJOIN: restart the fenced ex-primary as a follower of the new
    // primary — the fence clears, the epoch is adopted from the stream,
    // and it converges to the post-failover corpus
    primary.kill9();
    let mut rejoin_args = vec!["--replicate-from", follower.addr.as_str()];
    rejoin_args.extend_from_slice(&["--repl-poll-ms", "2"]);
    let rejoined = ServerProc::spawn(dir_p.path(), &rejoin_args, &[]);
    let mut rc = Client::connect(&rejoined.addr).expect("connect rejoined");
    wait_parity(&mut fc, &mut rc);
    assert_eq!(rc.stat("repl_role").unwrap(), 1.0);
    wait_stat(&mut rc, "repl_epoch", |v| v == 2.0, "epoch adopted on rejoin");
    assert_eq!(rc.stat("failover_fenced").unwrap(), 0.0, "fence cleared by rejoin");

    // nothing acked was lost, anywhere, and reads agree bit-identically
    assert_serves_exactly(&mut fc, &acked);
    assert_serves_exactly(&mut rc, &acked);
    let probes: Vec<CatVector> = acked.iter().step_by(5).map(|(_, v)| v.clone()).collect();
    assert_eq!(
        fc.query_batch(probes.clone(), 5).unwrap(),
        rc.query_batch(probes, 5).unwrap(),
        "rejoined follower diverges from the new primary"
    );
    let _ = fc.shutdown();
}

#[test]
fn torn_transfers_retry_to_bit_identical_convergence() {
    if !chaos_enabled() {
        return;
    }
    let dir_p = TempDir::new("chaos-torn-primary");
    let dir_f = TempDir::new("chaos-torn-follower");
    // the primary tears the first snapshot shard stream and the next two
    // frame ships; the follower must retry through all three
    let mut primary = ServerProc::spawn(
        dir_p.path(),
        &[],
        &[("CABIN_FAILPOINTS", "ship_snapshot_shard=err:1,ship_frames=err:2")],
    );
    let mut pc = Client::connect(&primary.addr).expect("connect primary");
    let acked = ingest(&mut pc, &vectors(21, 30));
    let follower = ServerProc::spawn(
        dir_f.path(),
        &["--replicate-from", primary.addr.as_str()],
        &[],
    );
    let mut fc = Client::connect(&follower.addr).expect("connect follower");
    wait_parity(&mut pc, &mut fc);
    assert_eq!(fc.stat("repl_diverged").unwrap(), 0.0);
    assert_serves_exactly(&mut fc, &acked);
    let probes: Vec<CatVector> = acked.iter().step_by(3).map(|(_, v)| v.clone()).collect();
    assert_eq!(
        pc.query_batch(probes.clone(), 5).unwrap(),
        fc.query_batch(probes, 5).unwrap(),
        "post-tear follower diverges from the primary"
    );
    let _ = fc.shutdown();
    let _ = pc.shutdown();
    primary.kill9();
}

#[test]
fn slow_primary_is_never_promoted_over() {
    if !chaos_enabled() {
        return;
    }
    let dir_p = TempDir::new("chaos-slow-primary");
    let dir_f = TempDir::new("chaos-slow-follower");
    // every request read on the primary dawdles 300 ms — far over any
    // healthy latency, far under the 2 s probe budget
    let mut primary = ServerProc::spawn(
        dir_p.path(),
        &[],
        &[("CABIN_FAILPOINTS", "conn_read=sleep:300")],
    );
    let mut pc = Client::connect(&primary.addr).expect("connect primary");
    ingest(&mut pc, &vectors(31, 4));
    let mut follower_args = vec!["--replicate-from", primary.addr.as_str()];
    follower_args.extend_from_slice(AUTO_PROMOTE);
    let follower = ServerProc::spawn(dir_f.path(), &follower_args, &[]);
    let mut fc = Client::connect(&follower.addr).expect("connect follower");
    // let a good number of slow probes land
    wait_stat(&mut fc, "failover_probes", |v| v >= 8.0, "probes under slowness");
    assert_eq!(
        fc.stat("failover_promotions").unwrap(),
        0.0,
        "a slow primary answering within the budget must never be promoted over"
    );
    assert_eq!(fc.stat("failover_probe_failures").unwrap(), 0.0);
    assert_eq!(fc.stat("repl_role").unwrap(), 1.0);
    primary.kill9();
}

#[test]
fn kill9_primary_auto_promotes_losing_no_acked_insert() {
    if !chaos_enabled() {
        return;
    }
    let dir_p = TempDir::new("chaos-kill9-primary");
    let dir_f = TempDir::new("chaos-kill9-follower");
    let mut primary = ServerProc::spawn(dir_p.path(), &[], &[]);
    let mut pc = Client::connect(&primary.addr).expect("connect primary");
    let mut acked = ingest(&mut pc, &vectors(41, 40));
    let mut follower_args = vec!["--replicate-from", primary.addr.as_str()];
    follower_args.extend_from_slice(AUTO_PROMOTE);
    let follower = ServerProc::spawn(dir_f.path(), &follower_args, &[]);
    let mut fc = Client::connect(&follower.addr).expect("connect follower");
    wait_parity(&mut pc, &mut fc);
    // the primary dies with no teardown whatsoever
    primary.kill9();
    wait_stat(&mut fc, "repl_role", |v| v == 2.0, "auto-promote after kill -9");
    assert_eq!(fc.stat("repl_epoch").unwrap(), 2.0);
    assert_eq!(fc.stat("failover_promotions").unwrap(), 1.0);
    // the survivor's journal must reconstruct the failover: at least the
    // configured 3 probe failures, all strictly before the promotion
    let dump = fc.events().expect("events dump");
    let fails = dump.matches("\"event\":\"probe_failed\"").count();
    assert!(fails >= 3, "expected ≥3 probe_failed journal events, saw {fails}");
    let first_fail =
        event_seq(&dump, "probe_failed").expect("probe_failed missing from journal");
    let promoted =
        event_seq(&dump, "auto_promoted").expect("auto_promoted missing from journal");
    assert!(
        first_fail < promoted,
        "journal out of order: probe_failed seq {first_fail} !< auto_promoted seq {promoted}"
    );
    // LOSES NOTHING: every insert the dead primary acked answers exactly
    assert_serves_exactly(&mut fc, &acked);
    // and the id line continues on the survivor
    let v = vectors(42, 1).pop().unwrap();
    let id = fc.insert(v.clone()).expect("insert on survivor");
    assert_eq!(id, acked.len());
    acked.push((id, v));
    assert_serves_exactly(&mut fc, &acked);
    let _ = fc.shutdown();
}
