//! Property tests for the blocked batch-scoring kernels: on random Q×N
//! tiles — including ragged tail tiles and ragged tail words — the
//! blocked multi-query kernels must be *bit-for-bit* identical to the
//! scalar `and_count_words`/`xor_count_words` path, and the routed top-k
//! built on them must equal a scalar per-query reference scan exactly
//! (same ids, same bitwise distances, same tie order).

use cabin::coordinator::router;
use cabin::coordinator::store::ShardedStore;
use cabin::coordinator::TopK;
use cabin::sketch::bitvec::{and_count_words, xor_count_words};
use cabin::sketch::cham::binhamming_from_stats;
use cabin::sketch::{BitVec, SketchMatrix};
use cabin::util::rng::Xoshiro256;

fn random_sketch(rng: &mut Xoshiro256, d: usize, ones: usize) -> BitVec {
    BitVec::from_indices(d, rng.sample_indices(d, ones.min(d)))
}

#[test]
fn tile_kernels_equal_scalar_on_random_shapes() {
    let mut rng = Xoshiro256::new(404);
    // dimensions exercising every unroll tail: sub-word, word-aligned,
    // 8-word-aligned, and ragged beyond both boundaries
    for &d in &[40usize, 64, 65, 448, 512, 520, 1000] {
        for &n in &[1usize, 7, 33, 64, 97] {
            for &q in &[1usize, 2, 5] {
                let rows: Vec<BitVec> =
                    (0..n).map(|_| random_sketch(&mut rng, d, d / 6 + 1)).collect();
                let m = SketchMatrix::from_sketches(&rows);
                let queries: Vec<BitVec> =
                    (0..q).map(|_| random_sketch(&mut rng, d, d / 5 + 1)).collect();
                let qwords: Vec<&[u64]> = queries.iter().map(|x| x.words()).collect();
                // tile size straddling the row count → ragged tail tile
                let tile = (n / 2 + 1).max(1);
                let mut start = 0;
                while start < n {
                    let end = (start + tile).min(n);
                    let len = end - start;
                    let mut and_out = vec![0usize; q * len];
                    let mut xor_out = vec![0usize; q * len];
                    m.tile_and_counts(&qwords, start, end, &mut and_out);
                    m.tile_xor_counts(&qwords, start, end, &mut xor_out);
                    for (qi, query) in queries.iter().enumerate() {
                        for i in 0..len {
                            let scalar_and = and_count_words(query.words(), m.row(start + i));
                            let scalar_xor = xor_count_words(query.words(), m.row(start + i));
                            assert_eq!(
                                and_out[qi * len + i],
                                scalar_and,
                                "and d={d} n={n} q={qi} row={}",
                                start + i
                            );
                            assert_eq!(
                                xor_out[qi * len + i],
                                scalar_xor,
                                "xor d={d} n={n} q={qi} row={}",
                                start + i
                            );
                        }
                    }
                    start = end;
                }
                // gathered (indexed-rerank) form: a scrambled row subset
                let gathered: Vec<u32> =
                    (0..n as u32).rev().filter(|r| r % 3 != 1).collect();
                let mut out = vec![0usize; gathered.len()];
                for query in &queries {
                    m.gather_and_counts(query.words(), &gathered, &mut out);
                    for (i, &r) in gathered.iter().enumerate() {
                        assert_eq!(
                            out[i],
                            and_count_words(query.words(), m.row(r as usize)),
                            "gather d={d} n={n} row={r}"
                        );
                    }
                }
            }
        }
    }
}

/// Scalar per-query reference: the exact arithmetic of the pre-blocking
/// router scan, offered in the same row order per shard.
fn reference_topk_batch(
    store: &ShardedStore,
    queries: &[BitVec],
    k: usize,
) -> Vec<Vec<cabin::coordinator::protocol::Hit>> {
    let d = store.sketch_dim();
    queries
        .iter()
        .map(|query| {
            let wq = query.count_ones() as f64;
            let partials = store.par_map_shards(|shard| {
                let mut best = TopK::new(k);
                for row in 0..shard.ids.len() {
                    let ip = and_count_words(query.words(), shard.rows.row(row)) as f64;
                    let dist =
                        2.0 * binhamming_from_stats(wq, shard.rows.weight(row) as f64, ip, d);
                    best.offer(shard.ids[row], dist);
                }
                best.into_sorted_hits()
            });
            let mut merged: Vec<_> = partials.into_iter().flatten().collect();
            merged.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.id.cmp(&b.id)));
            merged.dedup_by(|a, b| a.id == b.id);
            merged.truncate(k);
            merged
        })
        .collect()
}

#[test]
fn routed_blocked_topk_equals_scalar_reference() {
    let mut rng = Xoshiro256::new(405);
    let d = 330; // ragged tail word; tile_rows ≫ per-shard rows is fine
    let store = ShardedStore::new(3, d);
    let pts: Vec<BitVec> = (0..87).map(|_| random_sketch(&mut rng, d, 60)).collect();
    for chunk in pts.chunks(9) {
        store.insert_batch(chunk.to_vec());
    }
    let queries: Vec<BitVec> = (0..11).map(|_| random_sketch(&mut rng, d, 55)).collect();
    for k in [1usize, 4, 87, 200] {
        let blocked = router::topk_batch(&store, &queries, k);
        let reference = reference_topk_batch(&store, &queries, k);
        assert_eq!(blocked, reference, "k={k}");
    }
}
