//! XLA runtime integration: the AOT artifacts produce *exactly* the same
//! sketches as the native path, and kernel estimates match the rust
//! estimator to f32 tolerance. Skipped (with a loud message) when
//! `artifacts/` has not been built.

use cabin::data::CatVector;
use cabin::runtime::XlaEngine;
use cabin::sketch::cham;
use cabin::util::rng::Xoshiro256;

fn engine_or_skip() -> Option<XlaEngine> {
    match XlaEngine::try_default() {
        Some(e) => Some(e),
        None => {
            eprintln!("SKIP: artifacts/ not found — run `make artifacts` first");
            None
        }
    }
}

fn random_batch(engine: &XlaEngine, k: usize, seed: u64) -> Vec<CatVector> {
    let m = &engine.manifest;
    let mut rng = Xoshiro256::new(seed);
    (0..k)
        .map(|_| CatVector::random(m.n, 50 + rng.gen_range(100) as usize, m.c, &mut rng))
        .collect()
}

#[test]
fn xla_sketches_bit_identical_to_native() {
    let Some(engine) = engine_or_skip() else { return };
    let native = engine.native_equivalent().unwrap();
    let batch = random_batch(&engine, 8, 1);
    let xla = engine.cabin_sketch(&batch).unwrap();
    for (p, x) in batch.iter().zip(&xla) {
        let n = native.sketch(p);
        assert_eq!(&n, x, "XLA and native sketches diverge");
    }
}

#[test]
fn xla_allpairs_matches_native_estimator() {
    let Some(engine) = engine_or_skip() else { return };
    let native = engine.native_equivalent().unwrap();
    let batch = random_batch(&engine, 12, 2);
    let sketches: Vec<_> = batch.iter().map(|p| native.sketch(p)).collect();
    let est = engine.cham_allpairs(&sketches).unwrap();
    let k = sketches.len();
    for i in 0..k {
        for j in 0..k {
            let expect = if i == j {
                0.0
            } else {
                2.0 * cham::binhamming_occupancy(&sketches[i], &sketches[j])
            };
            let got = est[i * k + j];
            assert!(
                (got - expect).abs() < 1e-2 * expect.max(1.0),
                "({i},{j}): xla {got} native {expect}"
            );
        }
    }
}

#[test]
fn xla_cross_matches_native_estimator() {
    let Some(engine) = engine_or_skip() else { return };
    let native = engine.native_equivalent().unwrap();
    let queries: Vec<_> = random_batch(&engine, 4, 3)
        .iter()
        .map(|p| native.sketch(p))
        .collect();
    let corpus: Vec<_> = random_batch(&engine, 16, 4)
        .iter()
        .map(|p| native.sketch(p))
        .collect();
    let est = engine.cham_cross(&queries, &corpus).unwrap();
    for (qi, q) in queries.iter().enumerate() {
        for (ci, c) in corpus.iter().enumerate() {
            let expect = 2.0 * cham::binhamming_occupancy(q, c);
            let got = est[qi * corpus.len() + ci];
            assert!(
                (got - expect).abs() < 1e-2 * expect.max(1.0),
                "({qi},{ci}): {got} vs {expect}"
            );
        }
    }
}

#[test]
fn xla_fused_pipeline_matches_two_stage() {
    let Some(engine) = engine_or_skip() else { return };
    let batch = random_batch(&engine, 8, 5);
    let fused = engine.sketch_allpairs(&batch).unwrap();
    let sketches = engine.cabin_sketch(&batch).unwrap();
    let staged = engine.cham_allpairs(&sketches).unwrap();
    let k = batch.len();
    for i in 0..k * k {
        assert!(
            (fused[i] - staged[i]).abs() < 1e-2 * staged[i].max(1.0),
            "fused[{i}]={} staged={}",
            fused[i],
            staged[i]
        );
    }
    // and the estimates track the categorical ground truth
    for i in 0..k {
        for j in (i + 1)..k {
            let truth = batch[i].hamming(&batch[j]) as f64;
            let got = fused[i * k + j];
            assert!(
                (got - truth).abs() < 0.35 * truth + 40.0,
                "({i},{j}): estimate {got} truth {truth}"
            );
        }
    }
}

#[test]
fn manifest_sidecars_validate() {
    let Some(engine) = engine_or_skip() else { return };
    engine.manifest.validate_against_native().unwrap();
    assert_eq!(engine.manifest.d % 256, 0, "artifact d should be MXU-tiled");
}
