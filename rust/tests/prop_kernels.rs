//! Property tests for the runtime-dispatched popcount kernels: every
//! arm `cabin::sketch::kernels::available()` reports usable on this
//! machine must be *bit-for-bit* identical to a naive one-word-at-a-time
//! reference (and to the scalar oracle arm) on every input — random
//! word patterns, adversarial all-zeros/all-ones/alternating words, odd
//! word counts straddling every unroll and vector-width boundary, and
//! empty slices. A box without AVX2 simply has fewer arms to compare;
//! the `rust-avx2` CI lane runs this with AVX2 codegen forced on.

use cabin::sketch::kernels::{self, Isa};
use cabin::util::rng::Xoshiro256;

/// Trivially-correct reference: no unrolling, no SIMD, no shared code
/// with any arm under test.
fn naive_popcount(a: &[u64]) -> usize {
    a.iter().map(|w| w.count_ones() as usize).sum()
}

fn naive_pair(a: &[u64], b: &[u64], f: fn(u64, u64) -> u64) -> usize {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| f(x, y).count_ones() as usize)
        .sum()
}

/// Word counts covering every tail: empty, sub-unroll, the 4- and 8-way
/// unroll boundaries, the 4-word AVX2 / 8-word AVX-512 vector widths,
/// and ragged lengths beyond each.
const LENS: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100];

fn patterned(rng: &mut Xoshiro256, len: usize, pattern: usize) -> Vec<u64> {
    (0..len)
        .map(|i| match pattern {
            0 => rng.next_u64(),
            1 => 0,
            2 => !0,
            3 => 0xAAAA_AAAA_AAAA_AAAA,
            // sparse: realistic sketch occupancy, a few set bits per word
            _ => (1u64 << (rng.next_u64() % 64)) | (1u64 << (rng.next_u64() % 64)),
        })
        .collect()
}

#[test]
fn every_arm_matches_naive_reference_on_random_and_adversarial_words() {
    let arms = kernels::available();
    assert_eq!(arms[0].isa, Isa::Scalar, "scalar oracle must lead");
    let mut rng = Xoshiro256::new(406);
    for &len in LENS {
        for pa in 0..5 {
            for pb in 0..5 {
                let a = patterned(&mut rng, len, pa);
                let b = patterned(&mut rng, len, pb);
                let pop = naive_popcount(&a);
                let and = naive_pair(&a, &b, |x, y| x & y);
                let xor = naive_pair(&a, &b, |x, y| x ^ y);
                let or = naive_pair(&a, &b, |x, y| x | y);
                for t in &arms {
                    let name = t.isa.name();
                    let ctx = format!("{name} len={len} pa={pa} pb={pb}");
                    assert_eq!((t.popcount)(&a), pop, "popcount {ctx}");
                    assert_eq!((t.and_count)(&a, &b), and, "and {ctx}");
                    assert_eq!((t.xor_count)(&a, &b), xor, "xor {ctx}");
                    assert_eq!((t.or_count)(&a, &b), or, "or {ctx}");
                }
            }
        }
    }
}

#[test]
fn every_arm_matches_the_scalar_oracle_on_long_random_streaks() {
    // longer slices at random ragged lengths: the boundary cases above
    // prove the tails, this proves the steady-state main loops
    let mut rng = Xoshiro256::new(407);
    let scalar = kernels::table_for(Isa::Scalar).unwrap();
    for _ in 0..200 {
        let len = rng.usize_in(1, 513);
        let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
        for t in kernels::available() {
            let name = t.isa.name();
            assert_eq!((t.popcount)(&a), (scalar.popcount)(&a), "{name} len={len}");
            assert_eq!(
                (t.and_count)(&a, &b),
                (scalar.and_count)(&a, &b),
                "{name} len={len}"
            );
            assert_eq!(
                (t.xor_count)(&a, &b),
                (scalar.xor_count)(&a, &b),
                "{name} len={len}"
            );
            assert_eq!(
                (t.or_count)(&a, &b),
                (scalar.or_count)(&a, &b),
                "{name} len={len}"
            );
        }
    }
}

#[test]
fn active_arm_is_available_and_visible() {
    // the dispatched table is one of the comparable arms, so the two
    // properties above transitively cover every serving-path call
    let active = kernels::active();
    assert!(
        kernels::available().iter().any(|t| t.isa == active.isa),
        "active arm {:?} not in available()",
        active.isa
    );
    // and its wire code round-trips through the stats surface encoding
    let code = active.isa.code();
    assert!([0.0, 1.0, 2.0, 3.0].contains(&code), "{code}");
}
