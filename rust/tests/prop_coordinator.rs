//! Property tests over coordinator invariants: store conservation,
//! router correctness vs brute force, batcher id uniqueness.

use cabin::coordinator::router;
use cabin::coordinator::store::ShardedStore;
use cabin::sketch::{cham, BitVec};
use cabin::testing::PropRunner;

fn random_sketches(
    rng: &mut cabin::util::rng::Xoshiro256,
    n: usize,
    d: usize,
) -> Vec<BitVec> {
    (0..n)
        .map(|_| {
            let ones = 1 + rng.gen_range((d / 4) as u64) as usize;
            BitVec::from_indices(d, rng.sample_indices(d, ones))
        })
        .collect()
}

#[test]
fn prop_store_never_loses_points() {
    PropRunner::new("store conservation", 64).run(|rng, size| {
        let shards = 1 + rng.gen_range(6) as usize;
        let store = ShardedStore::new(shards, 64);
        let total = 1 + size / 2;
        let mut inserted = 0;
        while inserted < total {
            let sz = 1 + rng.gen_range(7) as usize;
            let batch = random_sketches(rng, sz, 64);
            inserted += batch.len();
            store.insert_batch(batch);
        }
        if store.len() != inserted {
            return Err(format!("len {} != inserted {}", store.len(), inserted));
        }
        let snap = store.snapshot_ordered();
        if snap.len() != inserted {
            return Err("snapshot lost points".into());
        }
        // ids dense and unique
        for (expect, (id, _)) in snap.iter().enumerate() {
            if *id != expect {
                return Err(format!("id gap at {expect}: {id}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rebalance_conserves_everything() {
    PropRunner::new("rebalance conservation", 48).run(|rng, size| {
        let store = ShardedStore::new(3, 32);
        let n = 4 + size / 2;
        let all = random_sketches(rng, n, 32);
        // single-shard-pressure insert pattern
        store.insert_batch(all.clone());
        store.rebalance(1);
        let snap = store.snapshot_ordered();
        if snap.len() != n {
            return Err(format!("lost points: {} != {n}", snap.len()));
        }
        for (i, (_, sk)) in snap.iter().enumerate() {
            if sk != &all[i] {
                return Err(format!("sketch {i} corrupted by rebalance"));
            }
        }
        let sizes = store.shard_sizes();
        let (max, min) = (
            *sizes.iter().max().unwrap() as i64,
            *sizes.iter().min().unwrap() as i64,
        );
        if max - min > (n as i64 / 2) + 2 {
            return Err(format!("still imbalanced: {sizes:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_router_topk_matches_bruteforce() {
    PropRunner::new("router == brute force", 48).run(|rng, size| {
        let d = 128;
        let n = 3 + size / 3;
        let store = ShardedStore::new(3, d);
        let pts = random_sketches(rng, n, d);
        for chunk in pts.chunks(4) {
            store.insert_batch(chunk.to_vec());
        }
        let q = random_sketches(rng, 1, d).pop().unwrap();
        let k = 1 + rng.gen_range(n as u64) as usize;
        let hits = router::topk(&store, &q, k);
        // brute force over the same estimator
        let mut brute: Vec<(usize, f64)> = pts
            .iter()
            .enumerate()
            .map(|(i, s)| {
                (
                    i,
                    2.0 * cham::binhamming_from_stats(
                        q.count_ones() as f64,
                        s.count_ones() as f64,
                        q.and_count(s) as f64,
                        d,
                    ),
                )
            })
            .collect();
        brute.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        brute.truncate(k);
        if hits.len() != brute.len() {
            return Err(format!("k mismatch {} vs {}", hits.len(), brute.len()));
        }
        for (h, (bi, bd)) in hits.iter().zip(&brute) {
            // distances must match exactly; ids may differ only on ties
            if (h.dist - bd).abs() > 1e-9 {
                return Err(format!("dist mismatch {} vs {} (ids {} {})", h.dist, bd, h.id, bi));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_ids_unique_under_concurrency() {
    use cabin::coordinator::batcher::{Batcher, BatcherConfig, SketchBackend};
    use cabin::coordinator::metrics::Metrics;
    use cabin::data::CatVector;
    use cabin::sketch::{CabinSketcher, SketchConfig};
    use std::sync::Arc;

    PropRunner::new("batcher id uniqueness", 8).run(|rng, size| {
        let store = Arc::new(ShardedStore::new(2, 64));
        let metrics = Arc::new(Metrics::new());
        let sk = CabinSketcher::from_config(SketchConfig::new(300, 8, 64, 1));
        let mut batcher = Batcher::start(
            BatcherConfig {
                max_batch: 1 + size / 16,
                max_delay: std::time::Duration::from_millis(1),
                queue_cap: 128,
            },
            SketchBackend::Native(sk),
            store.clone(),
            metrics,
        );
        let n_threads = 4;
        let per_thread = 8;
        let vecs: Vec<CatVector> = (0..n_threads * per_thread)
            .map(|_| CatVector::random(300, 15, 8, rng))
            .collect();
        let ids: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = vecs
                .chunks(per_thread)
                .map(|chunk| {
                    let sub = batcher.submitter.clone();
                    s.spawn(move || {
                        chunk
                            .iter()
                            .map(|v| sub.insert(v.clone()).unwrap())
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        batcher.shutdown();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != ids.len() {
            return Err("duplicate ids assigned".into());
        }
        if store.len() != ids.len() {
            return Err(format!("store {} != inserts {}", store.len(), ids.len()));
        }
        Ok(())
    });
}
