//! Index quality properties: the LSH candidate path must reproduce the
//! full-scan top-k on corpora with real neighbourhood structure
//! (recall@k ≥ 0.9 at the default banding parameters), degrade to the
//! exact path below the auto threshold, and never return fewer hits than
//! the full scan thanks to the per-shard fallback.

use cabin::coordinator::router::{self, QueryOpts};
use cabin::coordinator::store::ShardedStore;
use cabin::index::{IndexConfig, IndexMode};
use cabin::sketch::BitVec;
use cabin::util::rng::Xoshiro256;

const DIM: usize = 256;

fn random_sketch(rng: &mut Xoshiro256, ones: usize) -> BitVec {
    BitVec::from_indices(DIM, rng.sample_indices(DIM, ones))
}

/// Flip up to `flips` (not necessarily distinct) random bits.
fn perturb(center: &BitVec, flips: usize, rng: &mut Xoshiro256) -> BitVec {
    let mut v = center.clone();
    for _ in 0..flips {
        let i = rng.gen_range(DIM as u64) as usize;
        if v.get(i) {
            v.clear(i);
        } else {
            v.set(i);
        }
    }
    v
}

fn on_cfg() -> IndexConfig {
    // default banding parameters (L, b, probes); mode On so the property
    // is tested on every shard regardless of size
    IndexConfig {
        mode: IndexMode::On,
        ..Default::default()
    }
}

/// Clustered corpus: `centers` clusters of `members` sketches within
/// `member_flips` bit flips of their center, plus `noise` random sketches.
/// Returns (store, centers).
fn clustered_store(
    seed: u64,
    centers: usize,
    members: usize,
    member_flips: usize,
    noise: usize,
) -> (ShardedStore, Vec<BitVec>) {
    let mut rng = Xoshiro256::new(seed);
    let cs: Vec<BitVec> = (0..centers).map(|_| random_sketch(&mut rng, 40)).collect();
    let mut corpus: Vec<BitVec> = Vec::with_capacity(centers * members + noise);
    for c in &cs {
        for _ in 0..members {
            corpus.push(perturb(c, member_flips, &mut rng));
        }
    }
    for _ in 0..noise {
        corpus.push(random_sketch(&mut rng, 40));
    }
    let store = ShardedStore::with_index(3, DIM, &on_cfg(), 7);
    for chunk in corpus.chunks(64) {
        store.insert_batch(chunk.to_vec());
    }
    (store, cs)
}

#[test]
fn recall_at_k_is_at_least_0_9_at_default_config() {
    // Cluster members sit within ~10 sketch bits of a query near their
    // center; random noise sketches differ in ~65 bits. The full-scan
    // top-10 is therefore cluster-dominated, and a banded 16-bit sample
    // misses a 10-bit-perturbed neighbour in all 8 bands with probability
    // (1 - (1 - 16/256)^10)^8 ≈ 3e-3 before multi-probing — recall@10
    // lands near 1.0 and the 0.9 gate leaves real margin.
    let (store, centers) = clustered_store(1, 50, 24, 5, 800);
    let mut rng = Xoshiro256::new(2);
    let k = 10;
    let opts = QueryOpts::indexed(0, None);
    let mut hit = 0usize;
    let mut total = 0usize;
    for center in centers.iter().take(40) {
        let q = perturb(center, 3, &mut rng);
        let exact: Vec<usize> = router::topk(&store, &q, k).iter().map(|h| h.id).collect();
        let indexed: Vec<usize> = router::topk_with(&store, &q, k, &opts)
            .iter()
            .map(|h| h.id)
            .collect();
        assert_eq!(indexed.len(), exact.len(), "index shrank the result set");
        total += exact.len();
        hit += exact.iter().filter(|id| indexed.contains(*id)).count();
    }
    let recall = hit as f64 / total as f64;
    assert!(
        recall >= 0.9,
        "recall@{k} = {recall:.3} below 0.9 ({hit}/{total})"
    );
}

#[test]
fn below_auto_threshold_results_are_exactly_the_full_scan() {
    // Auto mode on a small corpus: every shard is under auto_min_rows, so
    // the indexed entry point must produce bit-identical results.
    let cfg = IndexConfig::default(); // Auto, min 1024
    let store = ShardedStore::with_index(2, DIM, &cfg, 9);
    let mut rng = Xoshiro256::new(3);
    let pts: Vec<BitVec> = (0..200).map(|_| random_sketch(&mut rng, 40)).collect();
    for chunk in pts.chunks(32) {
        store.insert_batch(chunk.to_vec());
    }
    let opts = QueryOpts::indexed(cfg.min_rows_for_index(), None);
    for q in pts.iter().take(12) {
        assert_eq!(
            router::topk_with(&store, q, 7, &opts),
            router::topk(&store, q, 7)
        );
    }
}

#[test]
fn indexed_recall_survives_a_rebalance() {
    // Force real row movement (one giant batch lands on one shard), then
    // verify the incrementally maintained indexes still reproduce the
    // full-scan top-k for self-queries — an exact duplicate collides in
    // every band, so any miss here means a move left stale positional
    // buckets behind.
    let mut rng = Xoshiro256::new(4);
    let pts: Vec<BitVec> = (0..600).map(|_| random_sketch(&mut rng, 40)).collect();
    let store = ShardedStore::with_index(3, DIM, &on_cfg(), 11);
    store.insert_batch(pts.clone());
    assert!(store.rebalance(1) > 0, "rebalance should have moved rows");
    let opts = QueryOpts::indexed(0, None);
    for (id, q) in pts.iter().enumerate().step_by(37) {
        let hits = router::topk_with(&store, q, 1, &opts);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, id, "self-query lost after rebalance");
        assert!(hits[0].dist < 1e-9);
    }
}

#[test]
fn k_zero_and_empty_store_are_benign_on_the_indexed_path() {
    let store = ShardedStore::with_index(2, DIM, &on_cfg(), 5);
    let mut rng = Xoshiro256::new(6);
    let q = random_sketch(&mut rng, 40);
    let opts = QueryOpts::indexed(0, None);
    assert!(router::topk_with(&store, &q, 5, &opts).is_empty());
    store.insert_batch(vec![q.clone()]);
    assert!(router::topk_with(&store, &q, 0, &opts).is_empty());
    let hits = router::topk_with(&store, &q, 5, &opts);
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].id, 0);
}
