//! Full clustering pipeline (Figures 6–10 protocol) on a topic-structured
//! twin: ground truth from full-dimensional k-mode, Cabin sketches cluster
//! almost as well, and the sketch path is faster.

use cabin::baselines::by_key;
use cabin::cluster::{kmode, kmode_binary, normalized_mutual_information, purity};
use cabin::data::synth::SynthSpec;
use cabin::util::timer::Stopwatch;

fn topic_twin(points: usize) -> cabin::data::CategoricalDataset {
    let mut spec = SynthSpec::small_demo();
    spec.num_points = points;
    spec.dim = 20_000;
    spec.topics = 4;
    spec.topic_sharpness = 0.9;
    spec.mean_density = 120.0;
    spec.max_density = 200;
    spec.generate(33)
}

#[test]
fn cabin_clustering_matches_ground_truth() {
    let ds = topic_twin(80);
    let k = 4;
    let truth = kmode(&ds, k, 20, 7).assignments;
    let red = by_key("cabin").unwrap().reduce(&ds, 1000, 7);
    let ours = kmode_binary(red.as_bits().unwrap(), k, 20, 7).assignments;
    let p = purity(&truth, &ours);
    let nmi = normalized_mutual_information(&truth, &ours);
    assert!(p > 0.75, "purity {p}");
    assert!(nmi > 0.4, "nmi {nmi}");
}

#[test]
fn sketch_clustering_is_faster_figure10_shape() {
    let ds = topic_twin(100);
    let k = 4;
    let sw = Stopwatch::start();
    let _ = kmode(&ds, k, 15, 7);
    let t_full = sw.elapsed_secs();
    let red = by_key("cabin").unwrap().reduce(&ds, 1000, 7);
    let bits = red.as_bits().unwrap();
    let sw = Stopwatch::start();
    let _ = kmode_binary(bits, k, 15, 7);
    let t_sketch = sw.elapsed_secs();
    assert!(
        t_sketch < t_full,
        "sketch clustering {t_sketch}s !< full {t_full}s"
    );
}

#[test]
fn quality_improves_with_sketch_dimension() {
    let ds = topic_twin(60);
    let k = 4;
    let truth = kmode(&ds, k, 20, 7).assignments;
    let score = |d: usize| {
        let red = by_key("cabin").unwrap().reduce(&ds, d, 7);
        let a = kmode_binary(red.as_bits().unwrap(), k, 20, 7).assignments;
        purity(&truth, &a)
    };
    let lo = score(32);
    let hi = score(2048);
    assert!(
        hi >= lo - 0.05,
        "purity should not degrade with dimension: d=32 {lo} vs d=2048 {hi}"
    );
    assert!(hi > 0.7, "purity at d=2048 too low: {hi}");
}
