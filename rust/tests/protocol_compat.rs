//! Wire-compatibility replay: canned PR 5–7-era request lines from
//! `protocol-fixtures/` (repo root) against a live server over real TCP,
//! asserting byte-stable replies.
//!
//! Each fixture file is a self-contained scenario replayed on a fresh
//! non-durable server, line by line:
//!
//! - `# ...` / blank — comment, skipped
//! - `>> <raw JSON>` — sent to the server verbatim
//! - `<< <line>` — the reply must equal `<line>` byte for byte
//! - `<<err <substring>` — the reply must be `ok:false` and its `error`
//!   text must contain `<substring>` (for store-level messages whose
//!   exact wording is owned by the store, not the protocol)
//! - `<<stats <name>=<value> ...` — the reply must be `ok:true` and each
//!   named field must equal the value (the string-keyed stats read)
//! - `<<metrics` — a framed stream reply: header `{"bytes":N,"ok":true}`
//!   (exactly those keys), then `N` bytes of Prometheus text
//! - `<<events` — the same framing, `N` bytes of flight-recorder JSONL;
//!   the journal is process-global so only the envelope shape is pinned
//!
//! The fixture files are the compat contract for the wire surface —
//! `tools/api_surface.py` fails CI when they change without
//! `docs/PROTOCOL.md` changing in the same commit. Living old spellings
//! they pin (the relative `ttl_ms` insert, the flat string-keyed stats
//! object) must keep answering until the deprecation window documented
//! there closes; the raw `"op"` stream forms' window closed in PR 9, so
//! the fixtures now pin their `unknown op` rejection instead — and pin
//! that pre-epoch acks/pongs stay byte-identical on non-durable servers.

use cabin::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use cabin::util::json::{self, Json};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// Pinned harness config: every fixture expectation (assigned ids,
/// exactly-zero duplicate distances, stats counters, `index_cfg_bands`)
/// is derived under exactly this corpus shape. Changing it invalidates
/// `protocol-fixtures/` — treat it like the fixtures themselves.
fn start_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let config = CoordinatorConfig {
        input_dim: 8,
        num_categories: 8,
        sketch_dim: 256,
        seed: 42,
        num_shards: 2,
        batcher: BatcherConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 64,
        },
        use_xla: false,
        heatmap_limit: 128,
        ..Default::default()
    };
    let coordinator = Arc::new(Coordinator::new(config));
    let (tx, rx) = std::sync::mpsc::sync_channel(1);
    let server = Arc::clone(&coordinator);
    let handle = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", |addr| {
                let _ = tx.send(addr);
            })
            .unwrap();
    });
    (rx.recv().unwrap(), handle)
}

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../protocol-fixtures")
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn connect(addr: &std::net::SocketAddr) -> Conn {
        let stream = TcpStream::connect(addr).unwrap();
        // a wedged server fails the test instead of hanging the run
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        Conn {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn send(&mut self, line: &str, ctx: &str) {
        writeln!(self.writer, "{line}")
            .unwrap_or_else(|e| panic!("{ctx}: send failed: {e}"));
    }

    fn read_reply(&mut self, ctx: &str) -> String {
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .unwrap_or_else(|e| panic!("{ctx}: read failed: {e}"));
        assert!(n > 0, "{ctx}: server closed the connection");
        line.trim_end_matches(['\r', '\n']).to_string()
    }
}

fn parse_reply(reply: &str, ctx: &str) -> Json {
    json::parse(reply)
        .unwrap_or_else(|e| panic!("{ctx}: reply {reply:?} is not JSON: {e:#}"))
}

/// `<<metrics`: framed header + exactly `bytes` of Prometheus payload.
fn expect_metrics(conn: &mut Conn, ctx: &str) {
    let header = conn.read_reply(ctx);
    let obj = parse_reply(&header, ctx);
    match &obj {
        Json::Obj(m) => assert_eq!(
            m.keys().map(|k| k.as_str()).collect::<Vec<_>>(),
            ["bytes", "ok"],
            "{ctx}: header {header:?}"
        ),
        other => panic!("{ctx}: header {other:?}"),
    }
    let ok = obj.get("ok").and_then(|v| v.as_bool());
    assert_eq!(ok, Some(true), "{ctx}: {header:?}");
    let bytes = obj.get("bytes").and_then(|v| v.as_usize()).unwrap();
    assert!(bytes > 0, "{ctx}: empty payload");
    let mut payload = vec![0u8; bytes];
    conn.reader
        .read_exact(&mut payload)
        .unwrap_or_else(|e| panic!("{ctx}: short payload: {e}"));
    let text = String::from_utf8(payload).unwrap();
    assert!(text.ends_with('\n'), "{ctx}: payload must end in a newline");
    assert!(
        text.contains("cabin_kernel_isa"),
        "{ctx}: payload is missing the kernel_isa gauge"
    );
}

/// `<<events`: framed header + exactly `bytes` of flight-recorder JSONL.
/// Journal content is process-global (other tests in this binary may have
/// recorded events), so each line is shape-checked against the journal
/// envelope rather than compared byte-for-byte.
fn expect_events(conn: &mut Conn, ctx: &str) {
    let header = conn.read_reply(ctx);
    let obj = parse_reply(&header, ctx);
    match &obj {
        Json::Obj(m) => assert_eq!(
            m.keys().map(|k| k.as_str()).collect::<Vec<_>>(),
            ["bytes", "ok"],
            "{ctx}: header {header:?}"
        ),
        other => panic!("{ctx}: header {other:?}"),
    }
    let ok = obj.get("ok").and_then(|v| v.as_bool());
    assert_eq!(ok, Some(true), "{ctx}: {header:?}");
    let bytes = obj.get("bytes").and_then(|v| v.as_usize()).unwrap();
    assert!(bytes > 0, "{ctx}: empty journal payload");
    let mut payload = vec![0u8; bytes];
    conn.reader
        .read_exact(&mut payload)
        .unwrap_or_else(|e| panic!("{ctx}: short payload: {e}"));
    let text = String::from_utf8(payload).unwrap();
    assert!(text.ends_with('\n'), "{ctx}: payload must end in a newline");
    for line in text.lines() {
        let ev = parse_reply(line, ctx);
        for key in ["seq", "ts_ms", "component", "event"] {
            assert!(
                ev.get(key).is_some(),
                "{ctx}: journal line missing {key:?}: {line}"
            );
        }
    }
    assert!(
        text.lines().any(|l| l.contains("\"event\":\"startup\"")),
        "{ctx}: no startup event in journal dump"
    );
}

/// `<<stats n=v ...`: string-keyed lookups into a flat `ok:true` object.
fn expect_stats(conn: &mut Conn, spec: &str, ctx: &str) {
    let reply = conn.read_reply(ctx);
    let obj = parse_reply(&reply, ctx);
    let ok = obj.get("ok").and_then(|v| v.as_bool());
    assert_eq!(ok, Some(true), "{ctx}: {reply:?}");
    for pair in spec.split_whitespace() {
        let (name, want) = pair
            .split_once('=')
            .unwrap_or_else(|| panic!("{ctx}: bad stats spec {pair:?}"));
        let want: f64 = want.parse().unwrap();
        let got = obj.get(name).and_then(|v| v.as_f64());
        assert_eq!(got, Some(want), "{ctx}: field {name}");
    }
}

/// `<<err substring`: an `ok:false` reply whose error text contains it.
fn expect_err(conn: &mut Conn, needle: &str, ctx: &str) {
    let reply = conn.read_reply(ctx);
    let obj = parse_reply(&reply, ctx);
    let ok = obj.get("ok").and_then(|v| v.as_bool());
    assert_eq!(ok, Some(false), "{ctx}: {reply:?}");
    let msg = obj.get("error").and_then(|v| v.as_str()).unwrap_or("");
    assert!(msg.contains(needle), "{ctx}: error {msg:?} lacks {needle:?}");
}

fn replay(path: &Path) {
    let (addr, server) = start_server();
    let mut conn = Conn::connect(&addr);
    let name = path.file_name().unwrap().to_string_lossy().to_string();
    let body = std::fs::read_to_string(path).unwrap();
    let mut outstanding = 0usize;
    for (ln, line) in body.lines().enumerate() {
        let ctx = format!("{name}:{}", ln + 1);
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(req) = line.strip_prefix(">> ") {
            conn.send(req, &ctx);
            outstanding += 1;
            continue;
        }
        assert!(outstanding > 0, "{ctx}: expectation without a request");
        outstanding -= 1;
        if let Some(exact) = line.strip_prefix("<< ") {
            let reply = conn.read_reply(&ctx);
            assert_eq!(reply, exact, "{ctx}: reply drifted");
        } else if let Some(needle) = line.strip_prefix("<<err ") {
            expect_err(&mut conn, needle, &ctx);
        } else if let Some(spec) = line.strip_prefix("<<stats ") {
            expect_stats(&mut conn, spec, &ctx);
        } else if line == "<<metrics" {
            expect_metrics(&mut conn, &ctx);
        } else if line == "<<events" {
            expect_events(&mut conn, &ctx);
        } else {
            panic!("{ctx}: unknown directive {line:?}");
        }
    }
    assert_eq!(outstanding, 0, "{name}: request left without an expectation");
    conn.send(r#"{"op":"shutdown"}"#, &name);
    assert_eq!(conn.read_reply(&name), r#"{"ok":true,"shutdown":true}"#);
    server.join().unwrap();
}

#[test]
fn replay_protocol_fixtures() {
    let dir = fixture_dir();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("missing {}: {e}", dir.display()))
        .map(|ent| ent.unwrap().path())
        .filter(|p| p.extension().is_some_and(|ext| ext == "txt"))
        .collect();
    files.sort();
    assert!(
        files.len() >= 5,
        "protocol-fixtures/ lost scenarios: {files:?}"
    );
    for file in &files {
        replay(file);
    }
}
