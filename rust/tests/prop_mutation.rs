//! Mutation equivalence property: a corpus that got to its final shape
//! through an arbitrary interleaving of deletes, upserts and
//! delete-then-reinserts must answer top-k queries *identically* to a
//! fresh store that only ever saw the survivors.
//!
//! This is the semantic contract behind swap-remove deletes and in-place
//! upserts: however the arena was shuffled by the mutation history —
//! holes filled by trailing rows, rows overwritten in place, ids retired
//! and reissued — the served ranking depends only on the surviving
//! (id, sketch) set. Distances must agree *bitwise* (same sketches, same
//! Cham estimator), and the comparison sorts by `(dist, id)` on both
//! sides so boundary ties cannot produce false mismatches. Runs over the
//! full-scan and LSH-indexed read paths alike.

use cabin::coordinator::protocol::Hit;
use cabin::coordinator::router::{self, QueryOpts};
use cabin::coordinator::store::ShardedStore;
use cabin::index::{IndexConfig, IndexMode};
use cabin::sketch::BitVec;
use cabin::util::rng::Xoshiro256;
use std::collections::BTreeMap;

const DIM: usize = 256;
const SHARDS: usize = 3;

fn sketch(rng: &mut Xoshiro256) -> BitVec {
    BitVec::from_indices(DIM, rng.sample_indices(DIM, 40))
}

/// One trial: mutate a store at random, then require it to serve exactly
/// like a fresh store of the survivors.
fn trial(seed: u64, index_mode: IndexMode) {
    let mut rng = Xoshiro256::new(seed);
    let cfg = IndexConfig {
        mode: index_mode,
        ..Default::default()
    };
    let mutated = ShardedStore::with_index(SHARDS, DIM, &cfg, seed);

    // survivors: the model the mutated store must converge to
    let mut survivors: BTreeMap<usize, BitVec> = BTreeMap::new();
    let initial: Vec<BitVec> = (0..60).map(|_| sketch(&mut rng)).collect();
    for (id, s) in mutated.insert_batch(initial.clone()).into_iter().zip(initial) {
        survivors.insert(id, s);
    }

    // an arbitrary mutation history over live ids
    for _ in 0..40 {
        let pick = |survivors: &BTreeMap<usize, BitVec>, rng: &mut Xoshiro256| {
            let keys: Vec<usize> = survivors.keys().copied().collect();
            keys[rng.gen_range(keys.len() as u64) as usize]
        };
        match rng.gen_range(3) {
            0 => {
                // delete
                let id = pick(&survivors, &mut rng);
                mutated.delete(id).unwrap();
                survivors.remove(&id);
            }
            1 => {
                // upsert: same id, new sketch (in place or cross-shard)
                let id = pick(&survivors, &mut rng);
                let s = sketch(&mut rng);
                mutated.upsert(id, s.clone(), 0).unwrap();
                survivors.insert(id, s);
            }
            _ => {
                // delete + reinsert: same sketch returns under a new id
                let id = pick(&survivors, &mut rng);
                let s = survivors.remove(&id).unwrap();
                mutated.delete(id).unwrap();
                let new_id = mutated.insert_batch(vec![s.clone()])[0];
                assert!(new_id > id, "ids are never reused");
                survivors.insert(new_id, s);
            }
        }
    }
    assert_eq!(mutated.live_len(), survivors.len());

    // a fresh store that only ever saw the survivors, in id order; its
    // ids are the survivors' ranks
    let fresh = ShardedStore::with_index(SHARDS, DIM, &cfg, seed);
    let fresh_ids = fresh.insert_batch(survivors.values().cloned().collect());
    assert_eq!(fresh_ids, (0..survivors.len()).collect::<Vec<_>>());
    let rank: BTreeMap<usize, usize> = survivors
        .keys()
        .enumerate()
        .map(|(r, &id)| (id, r))
        .collect();

    // point lookups agree
    for (id, s) in &survivors {
        assert_eq!(mutated.get(*id).as_ref(), Some(s), "id {id}");
        assert_eq!(fresh.get(rank[id]).as_ref(), Some(s));
    }

    // full rankings agree bitwise on both read paths: every hit of the
    // mutated store, translated through the id→rank map, must match the
    // fresh store's hit — same distance bits, same row
    let opts = match index_mode {
        IndexMode::Off => QueryOpts::full_scan(),
        _ => QueryOpts::indexed(0, None),
    };
    let k = survivors.len();
    let probes: Vec<BitVec> = (0..8)
        .map(|_| sketch(&mut rng))
        .chain(survivors.values().take(4).cloned())
        .collect();
    for q in &probes {
        let ranked = |hits: Vec<Hit>, translate: &dyn Fn(usize) -> usize| {
            let mut out: Vec<(u64, usize)> = hits
                .into_iter()
                .map(|h| (h.dist.to_bits(), translate(h.id)))
                .collect();
            out.sort_unstable();
            out
        };
        let a = ranked(router::topk_with(&mutated, q, k, &opts), &|id| rank[&id]);
        let b = ranked(router::topk_with(&fresh, q, k, &opts), &|id| id);
        assert_eq!(a, b, "seed {seed}, mode {index_mode:?}");
    }
}

#[test]
fn mutated_store_serves_identically_to_fresh_store_of_survivors() {
    for seed in [11, 22, 33] {
        trial(seed, IndexMode::Off);
        trial(seed, IndexMode::On);
    }
}
