//! End-to-end service test: coordinator over real TCP — concurrent
//! clients, insert/query/distance/stats/heatmap/shutdown.

use cabin::coordinator::client::Client;
use cabin::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, IndexConfig};
use cabin::data::{CatVector, synth::SynthSpec};
use std::sync::Arc;
use std::time::Duration;

fn start_server(dim: usize) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let config = CoordinatorConfig {
        input_dim: dim,
        num_categories: 16,
        sketch_dim: 256,
        seed: 5,
        num_shards: 3,
        batcher: BatcherConfig {
            max_batch: 16,
            max_delay: Duration::from_millis(1),
            queue_cap: 512,
        },
        use_xla: false,
        heatmap_limit: 128,
        index: IndexConfig::default(),
        persist: Default::default(),
        ..Default::default()
    };
    let coordinator = Arc::new(Coordinator::new(config));
    let (tx, rx) = std::sync::mpsc::sync_channel(1);
    let server = Arc::clone(&coordinator);
    let handle = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", |addr| {
                let _ = tx.send(addr);
            })
            .unwrap();
    });
    (rx.recv().unwrap(), handle)
}

fn twin(dim: usize, n: usize, seed: u64) -> Vec<CatVector> {
    let mut spec = SynthSpec::small_demo();
    spec.dim = dim;
    spec.num_categories = 16;
    spec.num_points = n;
    spec.generate(seed).points
}

#[test]
fn tcp_end_to_end() {
    let (addr, server) = start_server(800);
    let pts = twin(800, 30, 1);

    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.ping().unwrap();

    let mut ids = Vec::new();
    for p in &pts {
        ids.push(c.insert(p.clone()).unwrap());
    }
    assert_eq!(ids.len(), 30);

    // query with an inserted point: itself is the nearest hit
    let hits = c.query(pts[4].clone(), 3).unwrap();
    assert_eq!(hits.len(), 3);
    assert!(hits[0].dist < 1e-9, "{hits:?}");
    assert_eq!(hits[0].id, ids[4]);

    // distance symmetric, self-zero
    let d01 = c.distance(ids[0], ids[1]).unwrap();
    let d10 = c.distance(ids[1], ids[0]).unwrap();
    assert!((d01 - d10).abs() < 1e-9);
    assert_eq!(c.distance(ids[2], ids[2]).unwrap(), 0.0);

    // stats reflect traffic (single-field fetch: a missing field is an
    // error from the client helper, never a panic)
    assert_eq!(c.stat("inserts").unwrap(), 30.0);
    assert_eq!(c.stat("queries").unwrap(), 1.0);
    // index configuration is reported read-only alongside the counters
    assert_eq!(c.stat("index_cfg_bands").unwrap(), 8.0);
    assert!(c.stat("no_such_field").is_err());

    c.shutdown().unwrap();
    server.join().unwrap();
}

#[test]
fn tcp_concurrent_clients() {
    let (addr, server) = start_server(600);
    let pts = twin(600, 48, 2);
    std::thread::scope(|s| {
        for chunk in pts.chunks(12) {
            s.spawn(move || {
                let mut c = Client::connect(&addr.to_string()).unwrap();
                for p in chunk {
                    c.insert(p.clone()).unwrap();
                }
            });
        }
    });
    let mut c = Client::connect(&addr.to_string()).unwrap();
    assert_eq!(c.stat("inserts").unwrap(), 48.0);
    // concurrent inserts should have produced real batches
    assert!(c.stat("batches_flushed").unwrap() <= 48.0);
    c.shutdown().unwrap();
    server.join().unwrap();
}

#[test]
fn malformed_requests_get_errors_not_disconnects() {
    use std::io::{BufRead, BufReader, Write};
    let (addr, server) = start_server(100);
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    for bad in [
        "not json at all",
        r#"{"op":"unknown-op"}"#,
        r#"{"op":"insert","vec":[1,2]}"#, // wrong dim
        r#"{"op":"distance","a":0}"#,     // missing field
    ] {
        writeln!(w, "{bad}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "line: {line}");
    }
    // connection still usable
    writeln!(w, r#"{{"op":"ping"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"));
    writeln!(w, r#"{{"op":"shutdown"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    server.join().unwrap();
}

#[test]
fn heatmap_over_tcp_matches_native() {
    let (addr, server) = start_server(500);
    let pts = twin(500, 10, 3);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    for p in &pts {
        c.insert(p.clone()).unwrap();
    }
    match c
        .call(&cabin::coordinator::Request::Heatmap)
        .unwrap()
    {
        cabin::coordinator::Response::Heatmap { n, values } => {
            assert_eq!(n, 10);
            assert_eq!(values.len(), 100);
            // symmetric, zero diagonal
            for i in 0..n {
                assert_eq!(values[i * n + i], 0.0);
                for j in 0..n {
                    assert!((values[i * n + j] - values[j * n + i]).abs() < 1e-9);
                }
            }
            // estimates track the categorical truth loosely
            for i in 0..n {
                for j in (i + 1)..n {
                    let truth = pts[i].hamming(&pts[j]) as f64;
                    let est = values[i * n + j];
                    assert!(
                        (est - truth).abs() < 0.5 * truth + 40.0,
                        "({i},{j}): {est} vs {truth}"
                    );
                }
            }
        }
        other => panic!("{other:?}"),
    }
    c.shutdown().unwrap();
    server.join().unwrap();
}

#[test]
fn k_zero_over_the_wire_is_an_error_not_a_crash() {
    // Regression: a remote "query" with k == 0 used to reach the top-k
    // kernel, underflow hits[k - 1], and panic the shard workers — taking
    // the scatter/gather join() and the whole coordinator with it. The
    // protocol layer must reject it with an error response and keep
    // serving.
    use std::io::{BufRead, BufReader, Write};
    let (addr, server) = start_server(800);
    let pts = twin(800, 8, 3);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    for p in &pts {
        c.insert(p.clone()).unwrap();
    }

    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    for bad in [
        r#"{"op":"query","dim":800,"idx":[0],"val":[1],"k":0}"#,
        r#"{"op":"query_batch","dim":800,"k":0,"queries":[{"idx":[0],"val":[1]}]}"#,
    ] {
        writeln!(w, "{bad}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "line: {line}");
        assert!(line.contains("k must be >= 1"), "line: {line}");
    }
    // same connection — and the service — still answer real queries
    writeln!(w, r#"{{"op":"ping"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "line: {line}");

    let hits = c.query(pts[0].clone(), 3).unwrap();
    assert_eq!(hits.len(), 3);
    c.shutdown().unwrap();
    server.join().unwrap();
}

#[test]
fn query_batch_over_the_wire() {
    let (addr, server) = start_server(700);
    let pts = twin(700, 20, 4);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let mut ids = Vec::new();
    for p in &pts {
        ids.push(c.insert(p.clone()).unwrap());
    }

    // one round-trip answers all queries; each probe's own id comes first
    let results = c.query_batch(pts[..5].to_vec(), 3).unwrap();
    assert_eq!(results.len(), 5);
    for (qi, hits) in results.iter().enumerate() {
        assert_eq!(hits.len(), 3);
        assert_eq!(hits[0].id, ids[qi], "query {qi}: {hits:?}");
        assert!(hits[0].dist < 1e-9, "query {qi}: {hits:?}");
    }
    // batched answers must agree with the single-query path
    for (qi, hits) in results.iter().enumerate() {
        let single = c.query(pts[qi].clone(), 3).unwrap();
        assert_eq!(&single, hits, "query {qi}");
    }
    c.shutdown().unwrap();
    server.join().unwrap();
}
