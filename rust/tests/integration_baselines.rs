//! Cross-baseline integration: every Table 2 method runs end-to-end on a
//! realistic twin and the qualitative orderings the paper reports hold.

use cabin::analysis::rmse::{mae, rmse};
use cabin::baselines::{by_key, ALL_KEYS, DISCRETE_KEYS};
use cabin::data::registry::DatasetSpec;
use cabin::data::CategoricalDataset;

fn kos_twin(points: usize) -> CategoricalDataset {
    DatasetSpec::by_key("kos").unwrap().synth_spec(points).generate(42)
}

#[test]
fn all_methods_run_on_kos_twin() {
    let ds = kos_twin(30);
    for key in ALL_KEYS {
        let red = by_key(key).unwrap().reduce(&ds, 24, 3);
        assert_eq!(red.len(), ds.len(), "{key}");
        let e = red.estimate_hamming(0, 1);
        assert!(e.is_finite(), "{key}: estimate {e}");
        assert!(red.memory_bytes() > 0, "{key}");
    }
}

#[test]
fn discrete_methods_rmse_ordering_figure3() {
    // Figure 3's qualitative finding at moderate d: Cabin has the lowest
    // RMSE among discrete methods (FH/BCS can catch up only at large d).
    let ds = kos_twin(40);
    let d = 300;
    let mut scores: Vec<(String, f64)> = DISCRETE_KEYS
        .iter()
        .map(|k| (k.to_string(), rmse(&ds, &by_key(k).unwrap().reduce(&ds, d, 5))))
        .collect();
    scores.sort_by(|a, b| a.1.total_cmp(&b.1));
    println!("{scores:?}");
    let rank_of_cabin = scores.iter().position(|(k, _)| k == "cabin").unwrap();
    assert!(rank_of_cabin <= 1, "cabin ranked {rank_of_cabin}: {scores:?}");
    // H-LSH and KT markedly worse (their scaled-sample estimators)
    let get = |k: &str| scores.iter().find(|(n, _)| n == k).unwrap().1;
    assert!(get("cabin") < get("hlsh"));
    assert!(get("cabin") < get("kt"));
}

#[test]
fn mae_table4_shape_cabin_much_better_than_rest() {
    let ds = kos_twin(30);
    let d = 500;
    let cabin = mae(&ds, &by_key("cabin").unwrap().reduce(&ds, d, 7));
    // H-LSH's scaled-sample estimator is an order worse (the paper's 505
    // vs 24); SH merely worse at this small scale (its gap widens with
    // density — the BrainCell-twin regime measured by `repro table4`).
    let hlsh = mae(&ds, &by_key("hlsh").unwrap().reduce(&ds, d, 7));
    assert!(cabin * 2.0 < hlsh, "Table-4 shape: cabin {cabin} not ≪ hlsh {hlsh}");
    let sh = mae(&ds, &by_key("sh").unwrap().reduce(&ds, d, 7));
    assert!(cabin < sh, "Table-4 shape: cabin {cabin} !< sh {sh}");
}

#[test]
fn fh_bcs_improve_fast_with_dimension() {
    // The "few hash collisions" trend the paper points out for KOS.
    let ds = kos_twin(30);
    for key in ["fh", "bcs"] {
        let r = by_key(key).unwrap();
        let lo = rmse(&ds, &r.reduce(&ds, 128, 3));
        let hi = rmse(&ds, &r.reduce(&ds, 2048, 3));
        assert!(hi < lo, "{key}: rmse d=2048 {hi} !< d=128 {lo}");
    }
}

#[test]
fn supervised_selection_works_with_labels() {
    use cabin::baselines::feature_select::{chi2_scores, mutual_info_scores, project, select_top};
    let spec = DatasetSpec::by_key("kos").unwrap();
    let mut s = spec.synth_spec(60);
    s.topic_sharpness = 0.9;
    let (ds, labels) = s.generate_labeled(13);
    for scores in [chi2_scores(&ds, &labels), mutual_info_scores(&ds, &labels)] {
        let sel = select_top(&scores, 100);
        let proj = project(&ds, &sel);
        assert_eq!(proj.dim(), 100);
        // selected features should retain some cluster signal: same-topic
        // distance < cross-topic distance on the projection
        let mut same = (0.0, 0usize);
        let mut diff = (0.0, 0usize);
        for i in 0..proj.len() {
            for j in (i + 1)..proj.len() {
                let h = proj.points[i].hamming(&proj.points[j]) as f64;
                if labels[i] == labels[j] {
                    same = (same.0 + h, same.1 + 1);
                } else {
                    diff = (diff.0 + h, diff.1 + 1);
                }
            }
        }
        assert!(same.0 / same.1 as f64 <= diff.0 / diff.1 as f64 + 1e-9);
    }
}
