//! Replication integration: primary + follower coordinators in one
//! process, talking over real TCP.
//!
//! Covers the full follower lifecycle — snapshot bootstrap, WAL-tail
//! catch-up to seq parity, bit-identical read serving, the read-only
//! insert redirect, promotion to writable, restart-resume without
//! re-bootstrapping, and the retained-previous-segment serve path a
//! follower needs when it lags across a snapshot rotation. The
//! two-*process* lanes (kill -9 the real binary, promote the survivor)
//! live in `soak_recovery.rs`.

use cabin::coordinator::client::Client;
use cabin::coordinator::{Coordinator, CoordinatorConfig, WriteOpts};
use cabin::data::CatVector;
use cabin::persist::{FsyncPolicy, PersistConfig, PersistMode};
use cabin::replica::shipper::{self, Tail};
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIM: usize = 600;
const CATS: u16 = 10;
const SHARDS: usize = 2;

fn base_config(dir: &TempDir) -> CoordinatorConfig {
    CoordinatorConfig {
        input_dim: DIM,
        num_categories: CATS,
        sketch_dim: 128,
        seed: 5,
        num_shards: SHARDS,
        use_xla: false,
        persist: PersistConfig {
            mode: PersistMode::WalSnapshot,
            data_dir: Some(dir.path().to_path_buf()),
            fsync: FsyncPolicy::Never,
            snapshot_every: 0, // rotations only where a test forces them
            commit_window_us: 0,
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        },
        ..Default::default()
    }
}

fn follower_config(dir: &TempDir, primary: SocketAddr) -> CoordinatorConfig {
    CoordinatorConfig {
        replicate_from: Some(primary.to_string()),
        repl_poll_ms: 1,
        ..base_config(dir)
    }
}

fn serve(config: CoordinatorConfig) -> (SocketAddr, Arc<Coordinator>, std::thread::JoinHandle<()>) {
    let coordinator = Arc::new(Coordinator::try_new(config).unwrap());
    let (tx, rx) = std::sync::mpsc::sync_channel(1);
    let server = Arc::clone(&coordinator);
    let handle = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", |addr| {
                let _ = tx.send(addr);
            })
            .unwrap();
    });
    (rx.recv().unwrap(), coordinator, handle)
}

fn vectors(seed: u64, n: usize) -> Vec<CatVector> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| CatVector::random(DIM, 40, CATS, &mut rng)).collect()
}

/// Poll both servers' `persist_next_seq_shard{i}` stats until they agree
/// on every shard (the definition of catch-up parity).
fn wait_for_parity(primary: &mut Client, follower: &mut Client) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let mut equal = true;
        for si in 0..SHARDS {
            let field = format!("persist_next_seq_shard{si}");
            if primary.stat(&field).unwrap() != follower.stat(&field).unwrap() {
                equal = false;
                break;
            }
        }
        if equal {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "follower never reached seq parity with the primary"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn follower_bootstraps_catches_up_and_serves_identical_reads() {
    let p_dir = TempDir::new("repl-primary");
    let f_dir = TempDir::new("repl-follower");
    let (p_addr, _primary, p_handle) = serve(base_config(&p_dir));
    let mut pc = Client::connect(&p_addr.to_string()).unwrap();
    let pts = vectors(1, 40);
    // half before a snapshot (bootstrap path), half after (tail path)
    for v in &pts[..20] {
        pc.insert(v.clone()).unwrap();
    }
    assert_eq!(pc.snapshot().unwrap(), 1);
    for v in &pts[20..] {
        pc.insert(v.clone()).unwrap();
    }
    let (f_addr, follower, f_handle) = serve(follower_config(&f_dir, p_addr));
    let mut fc = Client::connect(&f_addr.to_string()).unwrap();
    wait_for_parity(&mut pc, &mut fc);
    // read-replica role and catch-up visible in stats
    assert_eq!(fc.stat("repl_role").unwrap(), 1.0);
    assert!(fc.stat("repl_frames_applied").unwrap() >= 20.0);
    assert_eq!(fc.stat("repl_diverged").unwrap(), 0.0);
    assert!(pc.stat("repl_frames_shipped").unwrap() >= 20.0);
    assert!(pc.stat("repl_snapshots_served").unwrap() >= 1.0);
    // wall-clock visibility lag: every tail-applied frame batch carries
    // the primary's commit_ms stamp, so the follower has recorded lag
    // samples and per-shard apply-age gauges by parity time
    assert!(fc.stat("repl_visibility_lag_count").unwrap() >= 1.0);
    assert!(fc.stat("repl_visibility_lag_p99_ms").unwrap() >= 0.0);
    assert!(fc.stat("repl_visibility_age_ms_shard0").unwrap() >= 0.0);
    assert!(fc.stat("repl_visibility_age_ms_shard1").unwrap() >= 0.0);
    // batched reads are bit-identical to the primary's
    let probes: Vec<CatVector> = pts[..8].to_vec();
    let from_primary = pc.query_batch(probes.clone(), 5).unwrap();
    let from_follower = fc.query_batch(probes, 5).unwrap();
    assert_eq!(from_primary, from_follower);
    // distance agrees too (same ids resolve on both sides)
    assert_eq!(fc.distance(3, 3).unwrap(), 0.0);
    assert_eq!(pc.distance(3, 17).unwrap(), fc.distance(3, 17).unwrap());
    // writes are rejected with a redirect naming the primary
    let err = fc.insert(pts[0].clone()).unwrap_err().to_string();
    assert!(err.contains("read-only replica"), "{err}");
    assert!(err.contains(&p_addr.to_string()), "{err}");
    // live inserts keep flowing through the tail
    let extra = vectors(2, 5);
    let mut extra_ids = Vec::new();
    for v in &extra {
        extra_ids.push(pc.insert(v.clone()).unwrap());
    }
    wait_for_parity(&mut pc, &mut fc);
    for (v, id) in extra.iter().zip(&extra_ids) {
        let hits = fc.query(v.clone(), 1).unwrap();
        assert_eq!(hits[0].id, *id);
        assert!(hits[0].dist < 1e-9);
    }
    fc.shutdown().unwrap();
    f_handle.join().unwrap();
    drop(follower);
    pc.shutdown().unwrap();
    p_handle.join().unwrap();
}

/// The mutable-corpus acceptance bar: a follower replaying a stream that
/// mixes inserts, deletes, upserts, a TTL expiry and rebalance moves must
/// end bit-identical to the primary — same shard layout (swap-remove
/// order mirrored), same `query_batch` answers — and its write redirect
/// must cover the new ops.
#[test]
fn follower_mirrors_mixed_mutation_stream_bit_identically() {
    let p_dir = TempDir::new("repl-mixed-primary");
    let f_dir = TempDir::new("repl-mixed-follower");
    // ttl_sweep_ms: 0 — this test expires the TTL row deterministically
    // through the store, not the timer
    let (p_addr, primary, p_handle) = serve(CoordinatorConfig {
        ttl_sweep_ms: 0,
        ..base_config(&p_dir)
    });
    let mut pc = Client::connect(&p_addr.to_string()).unwrap();
    let pts = vectors(7, 40);
    let mut ids = Vec::new();
    for v in &pts[..24] {
        ids.push(pc.insert(v.clone()).unwrap());
    }
    assert_eq!(pc.snapshot().unwrap(), 1); // follower bootstraps from here
    let (f_addr, follower, f_handle) = serve(CoordinatorConfig {
        ttl_sweep_ms: 0,
        ..follower_config(&f_dir, p_addr)
    });
    let mut fc = Client::connect(&f_addr.to_string()).unwrap();
    // the live tail is a mixed mutation stream
    pc.delete(ids[2]).unwrap();
    pc.delete(ids[13]).unwrap();
    pc.upsert_with(ids[5], pts[24].clone(), &WriteOpts::default()).unwrap();
    pc.upsert_with(ids[17], pts[25].clone(), &WriteOpts::default()).unwrap();
    let ttl_id = pc.insert_with(pts[26].clone(), &WriteOpts::ttl(1)).unwrap();
    for v in &pts[27..33] {
        pc.insert(v.clone()).unwrap();
    }
    primary.store.rebalance(1); // MoveOut/MoveIn pairs ride the stream
    assert_eq!(primary.store.sweep_expired(u64::MAX), 1); // → Delete frame
    wait_for_parity(&mut pc, &mut fc);
    // bit-identical arenas: ids, rows, cached weights and TTL deadlines,
    // shard by shard (swap-remove ordering mirrored exactly)
    let image = |s: &cabin::coordinator::store::Shard| {
        (s.ids.clone(), s.rows.clone(), s.expiry.clone())
    };
    assert_eq!(
        primary.store.map_shards(image),
        follower.store.map_shards(image),
        "follower arenas diverge from the primary's"
    );
    // bit-identical batched reads over the surviving corpus
    let probes: Vec<CatVector> = pts[6..14].to_vec();
    assert_eq!(
        pc.query_batch(probes.clone(), 5).unwrap(),
        fc.query_batch(probes, 5).unwrap()
    );
    // deleted and expired ids resolve on neither side
    for gone in [ids[2], ids[13], ttl_id] {
        assert!(pc.distance(gone, ids[0]).is_err(), "id {gone} on primary");
        assert!(fc.distance(gone, ids[0]).is_err(), "id {gone} on follower");
    }
    // the upserted rows answer with their replacement vectors
    for (id, replacement) in [(ids[5], &pts[24]), (ids[17], &pts[25])] {
        let hits = fc.query(replacement.clone(), 1).unwrap();
        assert_eq!(hits[0].id, id);
        assert!(hits[0].dist < 1e-9);
    }
    // the read-only redirect covers every write op
    for err in [
        fc.delete(ids[0]).unwrap_err().to_string(),
        fc.upsert_with(ids[0], pts[27].clone(), &WriteOpts::default())
            .unwrap_err()
            .to_string(),
        fc.insert_with(pts[27].clone(), &WriteOpts::ttl(5_000))
            .unwrap_err()
            .to_string(),
    ] {
        assert!(err.contains("read-only replica"), "{err}");
    }
    fc.shutdown().unwrap();
    f_handle.join().unwrap();
    drop(follower);
    pc.shutdown().unwrap();
    p_handle.join().unwrap();
}

#[test]
fn follower_restart_resumes_and_promotion_flips_writable() {
    let p_dir = TempDir::new("repl-promote-primary");
    let f_dir = TempDir::new("repl-promote-follower");
    let (p_addr, _primary, p_handle) = serve(base_config(&p_dir));
    let mut pc = Client::connect(&p_addr.to_string()).unwrap();
    let pts = vectors(3, 30);
    for v in &pts[..18] {
        pc.insert(v.clone()).unwrap();
    }
    // first follower life: bootstrap + parity, then graceful shutdown
    {
        let (f_addr, _f, f_handle) = serve(follower_config(&f_dir, p_addr));
        let mut fc = Client::connect(&f_addr.to_string()).unwrap();
        wait_for_parity(&mut pc, &mut fc);
        fc.shutdown().unwrap();
        f_handle.join().unwrap();
    }
    // primary keeps moving while the follower is down
    for v in &pts[18..] {
        pc.insert(v.clone()).unwrap();
    }
    // second follower life over the SAME dir: resume (no re-bootstrap:
    // the primary serves no second snapshot), catch up, then promote
    let (f_addr, _f, f_handle) = serve(follower_config(&f_dir, p_addr));
    let mut fc = Client::connect(&f_addr.to_string()).unwrap();
    wait_for_parity(&mut pc, &mut fc);
    assert_eq!(
        pc.stat("repl_snapshots_served").unwrap(),
        1.0,
        "a resumed follower must not re-bootstrap"
    );
    let (applied, epoch) = fc.promote().unwrap();
    assert_eq!(applied.len(), SHARDS);
    assert_eq!(applied.iter().sum::<u64>(), 30, "30 insert frames applied");
    assert_eq!(epoch, 2, "promotion bumps past the primary's epoch 1");
    assert_eq!(fc.stat("repl_role").unwrap(), 2.0);
    assert_eq!(fc.stat("repl_epoch").unwrap(), 2.0);
    // promoted: inserts continue the primary's id line
    let novel = vectors(4, 3);
    let id = fc.insert(novel[0].clone()).unwrap();
    assert_eq!(id, 30);
    let hits = fc.query(novel[0].clone(), 1).unwrap();
    assert_eq!(hits[0].id, id);
    assert!(hits[0].dist < 1e-9);
    // promote is idempotent — and does not bump the epoch twice
    let (again, epoch_again) = fc.promote().unwrap();
    assert_eq!(again.len(), SHARDS);
    assert_eq!(epoch_again, epoch, "re-promoting must not bump the epoch");
    // pre-promotion corpus still served exactly
    for (i, v) in pts.iter().enumerate() {
        let hits = fc.query(v.clone(), 1).unwrap();
        assert_eq!(hits[0].id, i, "id {i} lost across promotion");
        assert!(hits[0].dist < 1e-9);
    }
    fc.shutdown().unwrap();
    f_handle.join().unwrap();
    pc.shutdown().unwrap();
    p_handle.join().unwrap();
}

#[test]
fn lagging_followers_are_served_from_the_retained_segment() {
    // shipper-level determinism (no scheduler dependence): rotate the
    // primary, then ask for seqs the live segment no longer covers
    let p_dir = TempDir::new("repl-retention");
    let (p_addr, primary, p_handle) = serve(base_config(&p_dir));
    let mut pc = Client::connect(&p_addr.to_string()).unwrap();
    for v in &vectors(5, 12) {
        pc.insert(v.clone()).unwrap();
    }
    assert_eq!(pc.snapshot().unwrap(), 1);
    let p = primary.store.persistence().unwrap();
    let wpr = p.words_per_row();
    for si in 0..SHARDS {
        let absorbed = p.seq_view().base_seqs[si];
        if absorbed == 0 {
            continue; // this shard had no pre-rotation frames
        }
        // from_seq 0 predates the live base → retained gen-0 segment
        match shipper::wal_tail(p, si, 0, usize::MAX).unwrap() {
            Tail::Frames { frames, bytes, live_seq, .. } => {
                assert_eq!(frames, absorbed, "whole retained segment served");
                assert_eq!(live_seq, p.committed_seq(si));
                let replay = cabin::persist::wal::scan_frames(&bytes, wpr);
                assert_eq!(replay.records.len() as u64, frames);
                assert!(!replay.truncated);
            }
            _ => panic!("retained segment not served for shard {si}"),
        }
    }
    // a second rotation expires generation 0: now seq 0 needs a snapshot
    for v in &vectors(6, 4) {
        pc.insert(v.clone()).unwrap();
    }
    assert_eq!(pc.snapshot().unwrap(), 2);
    let needs_snapshot = (0..SHARDS).any(|si| {
        p.seq_view().prev.as_ref().is_some_and(|(_, bases)| bases[si] > 0)
            && matches!(
                shipper::wal_tail(p, si, 0, usize::MAX).unwrap(),
                Tail::SnapshotNeeded { .. }
            )
    });
    assert!(needs_snapshot, "expired history must demand a re-seed");
    // beyond the durable horizon = divergence, never served
    match shipper::wal_tail(p, 0, 1 << 40, 4096).unwrap() {
        Tail::Diverged { live_seq } => assert!(live_seq < 1 << 40),
        _ => panic!("a follower ahead of the primary must read as diverged"),
    }
    pc.shutdown().unwrap();
    p_handle.join().unwrap();
}

#[test]
fn repl_ops_and_replicas_fail_descriptively_without_persistence() {
    // a non-durable server cannot ship (no WAL to ship); the replica
    // client surfaces the server's error line
    let dir = TempDir::new("repl-nondurable");
    let cfg = CoordinatorConfig {
        persist: PersistConfig::default(), // off
        ..base_config(&dir)
    };
    let (addr, _c, handle) = serve(cfg);
    let mut rc = cabin::replica::follower::ReplClient::connect(&addr.to_string()).unwrap();
    let err = rc.fetch_snapshot_meta().unwrap_err().to_string();
    assert!(err.contains("--data-dir"), "{err}");
    let err = rc.fetch_tail(0, 0, 4096, None).unwrap_err().to_string();
    assert!(err.contains("--data-dir"), "{err}");
    // a mismatched replica configuration is refused at bootstrap with the
    // offending fields named
    let f_dir = TempDir::new("repl-mismatch");
    let durable_dir = TempDir::new("repl-mismatch-primary");
    let (p_addr, _p, p_handle) = serve(base_config(&durable_dir));
    let bad = CoordinatorConfig {
        seed: 999,
        ..follower_config(&f_dir, p_addr)
    };
    let err = Coordinator::try_new(bad).unwrap_err();
    assert!(format!("{err:#}").contains("seed"), "{err:#}");
    let mut pc = Client::connect(&p_addr.to_string()).unwrap();
    pc.shutdown().unwrap();
    p_handle.join().unwrap();
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}
