//! Observability integration: a real coordinator over TCP, driven
//! through both serving paths, then inspected through the two
//! operator-facing surfaces this crate exposes — the flat `stats`
//! fields (per-stage `stage_*` histogram summaries, executor panic
//! counter) and the `metrics_text` Prometheus exposition (validated
//! here with the same rules `tools/prom_lint.py` enforces in CI:
//! TYPE-before-samples, `_total` counter naming, cumulative histogram
//! buckets with `+Inf` == `_count`).

use cabin::coordinator::client::Client;
use cabin::coordinator::{Coordinator, CoordinatorConfig};
use cabin::data::CatVector;
use cabin::persist::{FsyncPolicy, PersistConfig, PersistMode};
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::Arc;

const DIM: usize = 400;
const CATS: u16 = 8;

fn config(dir: &TempDir) -> CoordinatorConfig {
    CoordinatorConfig {
        input_dim: DIM,
        num_categories: CATS,
        sketch_dim: 128,
        seed: 9,
        num_shards: 2,
        use_xla: false,
        persist: PersistConfig {
            mode: PersistMode::WalSnapshot,
            data_dir: Some(dir.path().to_path_buf()),
            fsync: FsyncPolicy::Never,
            snapshot_every: 0,
            commit_window_us: 0,
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        },
        ..Default::default()
    }
}

fn serve(config: CoordinatorConfig) -> (SocketAddr, Arc<Coordinator>) {
    let coordinator = Arc::new(Coordinator::try_new(config).unwrap());
    let (tx, rx) = std::sync::mpsc::sync_channel(1);
    let server = Arc::clone(&coordinator);
    std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", |addr| {
                let _ = tx.send(addr);
            })
            .unwrap();
    });
    (rx.recv().unwrap(), coordinator)
}

fn drive(client: &mut Client, inserts: usize, queries: usize) {
    let mut rng = Xoshiro256::new(31);
    for _ in 0..inserts {
        client
            .insert(CatVector::random(DIM, 24, CATS, &mut rng))
            .unwrap();
    }
    for _ in 0..queries {
        let hits = client
            .query(CatVector::random(DIM, 24, CATS, &mut rng), 5)
            .unwrap();
        assert!(!hits.is_empty());
    }
}

#[test]
fn stats_report_per_stage_histograms_for_both_paths() {
    let dir = TempDir::new("obs-stats");
    let (addr, _coordinator) = serve(config(&dir));
    let mut client = Client::connect(&addr.to_string()).unwrap();
    drive(&mut client, 64, 16);

    let stats: HashMap<String, f64> = client.stats().unwrap().into_iter().collect();

    // Write path: every insert passes through batcher queue → sketch →
    // placement → WAL append → fsync wait → reply.
    for stage in [
        "write_queue",
        "write_sketch",
        "write_place",
        "write_wal",
        "write_fsync",
        "write_reply",
    ] {
        let count = stats[&format!("stage_{stage}_count")];
        assert!(count >= 1.0, "stage_{stage}_count = {count}, expected ≥ 1");
    }
    // Read path: executor queue wait and scan fire per shard job, gather
    // once per request. Rerank only fires on indexed scans, so its
    // *fields* must exist but its count may be zero here.
    for stage in ["read_queue", "read_scan", "read_gather"] {
        let count = stats[&format!("stage_{stage}_count")];
        assert!(count >= 1.0, "stage_{stage}_count = {count}, expected ≥ 1");
    }
    assert!(stats.contains_key("stage_read_rerank_count"));
    // Quantile summaries ride along for each stage.
    assert!(stats.contains_key("stage_write_fsync_p99_ms"));
    assert!(stats.contains_key("stage_read_queue_p50_ms"));

    // No executor job panicked while serving this workload.
    assert_eq!(stats["executor_job_panics"], 0.0);
}

/// The subset of `tools/prom_lint.py` that matters for wire-format
/// correctness, reimplemented natively so the tier-1 suite catches
/// exposition bugs without a Python interpreter.
fn lint_exposition(text: &str) {
    let mut types: HashMap<&str, &str> = HashMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, kind) = (it.next().unwrap(), it.next().unwrap());
            assert!(
                types.insert(name, kind).is_none(),
                "duplicate # TYPE for {name}"
            );
        }
    }
    let mut buckets: HashMap<String, Vec<u64>> = HashMap::new();
    let mut inf: HashMap<String, u64> = HashMap::new();
    let mut counts: HashMap<String, u64> = HashMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let name = line
            .split(|c| c == '{' || c == ' ')
            .next()
            .unwrap_or_default();
        assert!(
            name.starts_with("cabin_"),
            "sample {name} missing cabin_ prefix"
        );
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|s| {
                let base = name.strip_suffix(s)?;
                (types.get(base) == Some(&"histogram")).then_some(base)
            })
            .unwrap_or(name);
        let kind = types
            .get(family)
            .unwrap_or_else(|| panic!("sample {name} has no # TYPE line"));
        match *kind {
            "counter" => assert!(
                name.ends_with("_total"),
                "counter {name} does not end in _total"
            ),
            "histogram" => {
                let value = line.rsplit(' ').next().unwrap();
                if name.ends_with("_bucket") {
                    let v: u64 = value.parse().unwrap();
                    if line.contains("le=\"+Inf\"") {
                        inf.insert(family.to_string(), v);
                    }
                    buckets.entry(family.to_string()).or_default().push(v);
                } else if name.ends_with("_count") {
                    counts.insert(family.to_string(), value.parse().unwrap());
                }
            }
            _ => {}
        }
    }
    assert!(!buckets.is_empty(), "no histogram families in exposition");
    for (family, series) in &buckets {
        assert!(
            series.windows(2).all(|w| w[0] <= w[1]),
            "histogram {family} buckets not cumulative: {series:?}"
        );
        let inf_v = inf.get(family).unwrap_or_else(|| {
            panic!("histogram {family} missing +Inf bucket")
        });
        let count = counts.get(family).unwrap_or_else(|| {
            panic!("histogram {family} missing _count")
        });
        assert_eq!(inf_v, count, "histogram {family}: +Inf bucket != _count");
    }
}

#[test]
fn metrics_text_exposes_lintable_prometheus_families() {
    let dir = TempDir::new("obs-prom");
    let (addr, _coordinator) = serve(config(&dir));
    let mut client = Client::connect(&addr.to_string()).unwrap();
    drive(&mut client, 48, 12);

    let text = client.metrics_text().unwrap();
    lint_exposition(&text);

    // Both serving paths surface as native histogram families, and the
    // plain request/latency metrics keep their conventional names.
    for needle in [
        "# TYPE cabin_stage_write_fsync_seconds histogram",
        "# TYPE cabin_stage_read_queue_seconds histogram",
        "# TYPE cabin_query_latency_seconds histogram",
        "# TYPE cabin_inserts_total counter",
    ] {
        assert!(text.contains(needle), "exposition missing {needle:?}");
    }
    // stage_* flat summaries are exposed as histograms, not doubled as
    // counters.
    assert!(!text.contains("cabin_stage_write_wal_count_total"));

    // The client can scrape repeatedly on one connection (framing stays
    // in sync), and ordinary ops still work afterwards.
    let again = client.metrics_text().unwrap();
    assert!(again.contains("cabin_inserts_total"));
    client.ping().unwrap();
}

#[test]
fn events_dump_reports_lifecycle_journal() {
    let dir = TempDir::new("obs-events");
    let (addr, _coordinator) = serve(config(&dir));
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let events = client.events().unwrap();
    // The journal is process-global, so alongside this server's startup
    // event there may be events from concurrently running tests — assert
    // shape, not exact content.
    assert!(
        events.lines().any(|l| l.contains("\"event\":\"startup\"")),
        "startup event missing from journal:\n{events}"
    );
    for line in events.lines() {
        let obj = cabin::util::json::parse(line)
            .unwrap_or_else(|e| panic!("journal line is not JSON ({e}): {line}"));
        assert!(obj.get("seq").is_some(), "journal line missing seq: {line}");
        assert!(obj.get("ts_ms").is_some(), "journal line missing ts_ms: {line}");
        obj.req_str("component").unwrap();
        obj.req_str("event").unwrap();
    }
    // Repeat dumps stay framed on one connection; ordinary ops still work.
    let again = client.events().unwrap();
    assert!(again.contains("\"event\":\"startup\""));
    client.ping().unwrap();
}

#[test]
fn stalled_executor_job_surfaces_as_traced_slow_op() {
    const TRACE: u64 = 777_000_111;
    let dir = TempDir::new("obs-slowop");
    let mut cfg = config(&dir);
    cfg.slow_op_ms = 10;
    let (addr, _coordinator) = serve(cfg);
    let mut client = Client::connect(&addr.to_string())
        .unwrap()
        .with_trace(TRACE);
    drive(&mut client, 8, 0);
    let mut rng = Xoshiro256::new(77);
    // The slow-op threshold and the failpoint registry are both
    // process-global: a concurrently constructed coordinator resets the
    // threshold, and another test's query can consume the armed sleeps.
    // Reassert both and retry instead of flaking.
    let mut found = false;
    for _ in 0..10 {
        cabin::obs::set_slow_op_ms(10);
        // both shard submits sleep 20 ms → the query breaches 10 ms
        cabin::fault::arm("executor_submit", "sleep:20:2").unwrap();
        client
            .query(CatVector::random(DIM, 24, CATS, &mut rng), 3)
            .unwrap();
        let events = client.events().unwrap();
        if events.lines().any(|l| {
            l.contains("\"event\":\"slow_op\"") && l.contains(&format!("\"trace\":{TRACE}"))
        }) {
            found = true;
            break;
        }
    }
    cabin::fault::disarm("executor_submit");
    assert!(
        found,
        "stalled query never surfaced as a slow_op journal event with trace {TRACE}"
    );
}
