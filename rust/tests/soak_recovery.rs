//! Crash-recovery soak: `kill -9` the real server binary mid-ingest and
//! verify that every *acknowledged* insert survives the restart.
//!
//! This is the durability contract end-to-end: the store WAL-commits each
//! batch before the batcher acknowledges it, so an insert whose response
//! reached the client must be recoverable — even though the process dies
//! with no teardown whatsoever. (With `--fsync always` the same holds
//! across power loss; a SIGKILL alone cannot lose OS-buffered writes, so
//! the test is deterministic either way.)
//!
//! One quick round runs in the tier-1 gate; the scheduled CI soak lane
//! sets `CABIN_SOAK=1` for more rounds with a larger corpus.

use cabin::coordinator::client::Client;
use cabin::coordinator::WriteOpts;
use cabin::data::CatVector;
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};

const DIM: usize = 400;

/// Kills the child on drop so a failing assert can't leak a server.
struct ServerProc {
    child: Child,
    pub addr: String,
}

impl ServerProc {
    fn spawn(data_dir: &std::path::Path, extra_args: &[&str]) -> ServerProc {
        Self::spawn_at(data_dir, "127.0.0.1:0", extra_args)
    }

    /// As [`ServerProc::spawn`], with an explicit bind address — a
    /// restarted primary must come back on the port its follower targets.
    fn spawn_at(data_dir: &std::path::Path, addr: &str, extra_args: &[&str]) -> ServerProc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_cabin"))
            .args([
                "serve",
                "--addr",
                addr,
                "--dim",
                "400",
                "--categories",
                "8",
                "--sketch-dim",
                "128",
                "--seed",
                "3",
                "--shards",
                "2",
                "--no-xla=true",
                "--max-delay-ms",
                "1",
                "--fsync",
                "always",
            ])
            .args(extra_args)
            .arg("--data-dir")
            .arg(data_dir)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn cabin serve");
        // `serve` prints "[serve] bound <addr>" once the listener is up
        let stdout = child.stdout.take().expect("child stdout");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            let line = lines
                .next()
                .expect("server exited before binding")
                .expect("read server stdout");
            if let Some(bound) = line.strip_prefix("[serve] bound ") {
                break bound.trim().to_string();
            }
        };
        // drain the rest of stdout in the background so the child can
        // never block on a full pipe
        std::thread::spawn(move || for _ in lines {});
        ServerProc { child, addr }
    }

    /// Hard stop: SIGKILL, no shutdown request, no flush.
    fn kill9(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        self.kill9();
    }
}

/// The durability contract, per commit mode: `window_us(round)` selects
/// the `--commit-window-us` each server life runs with, so the soak covers
/// both the synchronous per-batch commit path and group commit (where the
/// ack waits for the window's coalesced fsync — an acked insert must
/// survive `kill -9` identically in both).
fn soak_rounds(dir: &TempDir, rounds: usize, per_round: usize, window_us: &dyn Fn(usize) -> u64) {
    let mut rng = Xoshiro256::new(99);
    // (id, vector) pairs whose insert was acknowledged before a kill
    let mut acked: Vec<(usize, CatVector)> = Vec::new();

    for round in 0..rounds {
        let window = window_us(round).to_string();
        let mut server = ServerProc::spawn(dir.path(), &["--commit-window-us", window.as_str()]);
        let mut c = Client::connect(&server.addr).expect("connect");
        // every previously-acked insert must already be back
        for (id, v) in &acked {
            let hits = c.query(v.clone(), 1).expect("query recovered corpus");
            assert_eq!(hits[0].id, *id, "round {round}: id {id} lost after kill -9");
            assert!(
                hits[0].dist < 1e-9,
                "round {round}: id {id} corrupted (dist {})",
                hits[0].dist
            );
        }
        // ingest this round's batch; record each ack
        for _ in 0..per_round {
            let v = CatVector::random(DIM, 50, 8, &mut rng);
            let id = c.insert(v.clone()).expect("insert");
            acked.push((id, v));
        }
        // mid-stream hard stop: some queued-but-unacked work may exist in
        // the batcher; acked work must survive regardless
        server.kill9();
    }

    // final life: everything ever acknowledged is present and exact
    let final_window = window_us(rounds).to_string();
    let mut server =
        ServerProc::spawn(dir.path(), &["--commit-window-us", final_window.as_str()]);
    let mut c = Client::connect(&server.addr).expect("connect final");
    assert_eq!(acked.len(), rounds * per_round);
    for (id, v) in &acked {
        let hits = c.query(v.clone(), 1).expect("query final corpus");
        assert_eq!(hits[0].id, *id, "id {id} lost in final recovery");
        assert!(hits[0].dist < 1e-9);
        assert_eq!(c.distance(*id, *id).unwrap(), 0.0);
    }
    assert_eq!(c.stat("persist_cfg_mode").unwrap(), 2.0);
    assert_eq!(
        c.stat("persist_cfg_commit_window_us").unwrap(),
        window_us(rounds) as f64
    );
    let _ = c.shutdown();
    let _ = server.child.wait();
}

#[test]
fn kill9_mid_ingest_then_restart_recovers_every_acked_insert() {
    let soak = std::env::var("CABIN_SOAK").ok().as_deref() == Some("1");
    let (rounds, per_round) = if soak { (4, 120) } else { (1, 40) };
    let dir = TempDir::new("soak-recovery");
    // synchronous per-batch commits: the pre-group-commit contract
    soak_rounds(&dir, rounds, per_round, &|_round| 0);
}

#[test]
fn kill9_with_group_commit_recovers_every_acked_insert() {
    let soak = std::env::var("CABIN_SOAK").ok().as_deref() == Some("1");
    let (rounds, per_round) = if soak { (4, 120) } else { (1, 40) };
    let dir = TempDir::new("soak-recovery-group");
    // alternate window sizes across lives: the recovered corpus must be
    // indifferent to the commit mode that wrote (or re-reads) it
    soak_rounds(&dir, rounds, per_round, &|round| {
        if round % 2 == 0 {
            2_000
        } else {
            500
        }
    });
}

/// The mutable-corpus durability contract under `kill -9`: a workload
/// mixing inserts, deletes, upserts and short-TTL inserts — with the TTL
/// sweeper and dead-frame compaction armed — must recover every
/// acknowledged write exactly. Acked deletes stay gone forever, acked
/// upserts answer with their replacement vector, and TTL rows are
/// (eventually) swept by the next life's sweeper even when the process
/// that inserted them died before their deadline.
#[test]
fn kill9_mid_mixed_mutation_stream_recovers_every_acked_write() {
    use std::collections::BTreeMap;
    let soak = std::env::var("CABIN_SOAK").ok().as_deref() == Some("1");
    let (rounds, per_round) = if soak { (4, 105) } else { (1, 35) };
    let dir = TempDir::new("soak-mutations");
    let args = [
        "--commit-window-us",
        "500",
        "--ttl-sweep-ms",
        "50",
        "--compact-dead-frames",
        "64",
    ];
    let mut rng = Xoshiro256::new(77);
    // the acked model: id → expected vector for live rows, plus the ids
    // whose delete was acked (must never come back) and the TTL ids
    // (must eventually be swept, in whichever life the sweeper catches up)
    let mut live: BTreeMap<usize, CatVector> = BTreeMap::new();
    let mut dead: Vec<usize> = Vec::new();
    let mut ttl_ids: Vec<usize> = Vec::new();

    for round in 0..=rounds {
        let mut server = ServerProc::spawn(dir.path(), &args);
        let mut c = Client::connect(&server.addr).expect("connect");
        // every acked write must be back exactly as acknowledged
        for (id, v) in &live {
            let hits = c.query(v.clone(), 1).expect("query recovered corpus");
            assert_eq!(hits[0].id, *id, "round {round}: id {id} lost after kill -9");
            assert!(
                hits[0].dist < 1e-9,
                "round {round}: id {id} answers a stale vector (dist {})",
                hits[0].dist
            );
        }
        for id in &dead {
            assert!(
                c.distance(*id, *id).is_err(),
                "round {round}: acked delete of id {id} resurrected by recovery"
            );
        }
        if round == rounds {
            // final life: the CLI flags really reached the config...
            assert_eq!(c.stat("persist_cfg_compact_dead_frames").unwrap(), 64.0);
            // ...and every TTL row is swept once this life's sweeper
            // catches up with the (long-past) deadlines
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
            for id in &ttl_ids {
                while c.distance(*id, *id).is_ok() {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "ttl id {id} never expired"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(25));
                }
            }
            let _ = c.shutdown();
            let _ = server.child.wait();
            return;
        }
        // this round's mixed stream; each op is acked before the model
        // records it, so a mid-stream kill can only lose unacked work
        for i in 0..per_round {
            let v = CatVector::random(DIM, 50, 8, &mut rng);
            if i % 7 == 3 && !live.is_empty() {
                let &id = live.keys().next().unwrap();
                c.delete(id).expect("delete");
                live.remove(&id);
                dead.push(id);
            } else if i % 7 == 5 && !live.is_empty() {
                let &id = live.keys().next_back().unwrap();
                c.upsert_with(id, v.clone(), &WriteOpts::default()).expect("upsert");
                live.insert(id, v);
            } else if i % 7 == 6 {
                ttl_ids.push(c.insert_with(v, &WriteOpts::ttl(1)).expect("insert_ttl"));
            } else {
                let id = c.insert(v.clone()).expect("insert");
                live.insert(id, v);
            }
        }
        // mid-stream hard stop — the sweeper and compaction may be
        // mid-flight; neither may damage acked history
        server.kill9();
    }
}

// ---------------------------------------------------------------------------
// Two-process replication lanes: a real follower process replicating a
// real primary process, with kill -9 on both sides.

const SHARDS: usize = 2;

/// Ingest `n` vectors through `threads` concurrent clients, returning
/// every acknowledged `(id, vector)` pair.
fn acked_ingest(addr: &str, threads: usize, n: usize, seed: u64) -> Vec<(usize, CatVector)> {
    let acked = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for t in 0..threads {
            let acked = &acked;
            s.spawn(move || {
                let mut c = Client::connect(addr).expect("connect ingest");
                let mut rng = Xoshiro256::new(seed + t as u64);
                for _ in 0..n / threads {
                    let v = CatVector::random(DIM, 50, 8, &mut rng);
                    let id = c.insert(v.clone()).expect("insert");
                    acked.lock().unwrap().push((id, v));
                }
            });
        }
    });
    acked.into_inner().unwrap()
}

/// Poll both servers until their per-shard durable seq horizons agree.
fn wait_parity(primary: &mut Client, follower: &mut Client) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let equal = (0..SHARDS).all(|si| {
            let field = format!("persist_next_seq_shard{si}");
            primary.stat(&field).unwrap() == follower.stat(&field).unwrap()
        });
        if equal {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "follower never reached seq parity"
        );
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
}

fn assert_serves_exactly(c: &mut Client, acked: &[(usize, CatVector)], every: usize) {
    for (id, v) in acked.iter().step_by(every.max(1)) {
        let hits = c.query(v.clone(), 1).expect("query");
        assert_eq!(hits[0].id, *id, "id {id} lost");
        assert!(hits[0].dist < 1e-9, "id {id} corrupted (dist {})", hits[0].dist);
    }
}

#[test]
fn replication_follower_survives_kill9_and_promotes_losing_no_acked_insert() {
    let soak = std::env::var("CABIN_SOAK").ok().as_deref() == Some("1");
    // soak: 8 threads × 6250 × 2 phases = a 100k-row durable corpus (the
    // acceptance bar); fast mode keeps the same shape at tier-1 scale
    let (threads, phase) = if soak { (8, 6_250) } else { (2, 30) };
    let dir_p = TempDir::new("soak-repl-primary");
    let dir_f = TempDir::new("soak-repl-follower");
    let mut primary = ServerProc::spawn(dir_p.path(), &["--commit-window-us", "500"]);
    let mut acked = acked_ingest(&primary.addr, threads, threads * phase, 7);
    // simulate a follower killed mid-bootstrap: stray snapshot leftovers
    // without a MANIFEST must be harmless on the next start
    std::fs::write(dir_f.path().join("snap-1-shard-0.bin"), b"torn bootstrap").unwrap();
    let repl_args = ["--replicate-from", primary.addr.as_str()];
    let mut follower = ServerProc::spawn(dir_f.path(), &repl_args);
    // kill the follower mid-catch-up; the restart must resume cleanly
    follower.kill9();
    let follower = ServerProc::spawn(dir_f.path(), &repl_args);
    // keep ingesting while the follower races to catch up
    acked.extend(acked_ingest(&primary.addr, threads, threads * phase, 1_000));
    let mut pc = Client::connect(&primary.addr).expect("connect primary");
    let mut fc = Client::connect(&follower.addr).expect("connect follower");
    wait_parity(&mut pc, &mut fc);
    assert_eq!(fc.stat("repl_role").unwrap(), 1.0);
    assert_eq!(fc.stat("repl_diverged").unwrap(), 0.0);
    // the primary dies hard; the caught-up follower takes over
    primary.kill9();
    let (applied, epoch) = fc.promote().expect("promote");
    assert_eq!(applied.len(), SHARDS);
    assert_eq!(epoch, 2, "promotion must bump past the dead primary's epoch");
    assert_eq!(fc.stat("repl_role").unwrap(), 2.0);
    // LOSES NOTHING: every insert the dead primary ever acked answers
    // exactly on the promoted follower (sampled in soak mode for time)
    let every = if soak { 97 } else { 1 };
    assert_serves_exactly(&mut fc, &acked, every);
    // and the promoted follower is a real primary now: writes flow and
    // continue the id line
    let mut rng = Xoshiro256::new(2);
    let v = CatVector::random(DIM, 50, 8, &mut rng);
    let id = fc.insert(v.clone()).expect("insert on promoted follower");
    assert_eq!(id, acked.len(), "promoted id line must continue the primary's");
    let _ = fc.shutdown();
}

#[test]
fn replication_primary_kill9_mid_ship_leaves_a_consistent_resumable_prefix() {
    let soak = std::env::var("CABIN_SOAK").ok().as_deref() == Some("1");
    let (threads, phase) = if soak { (8, 1_200) } else { (2, 30) };
    let dir_p = TempDir::new("soak-repl-midship-primary");
    let dir_f = TempDir::new("soak-repl-midship-follower");
    let mut primary = ServerProc::spawn(dir_p.path(), &[]);
    let primary_addr = primary.addr.clone();
    let follower = ServerProc::spawn(dir_f.path(), &["--replicate-from", &primary_addr]);
    // ingest and kill the primary immediately — shipping is mid-flight
    let acked = acked_ingest(&primary_addr, threads, threads * phase, 21);
    primary.kill9();
    // the follower keeps serving its consistent prefix: stats answer and
    // any vector it returns at distance 0 is the exact acked one
    let mut fc = Client::connect(&follower.addr).expect("connect follower");
    assert_eq!(fc.stat("repl_role").unwrap(), 1.0);
    assert_eq!(fc.stat("repl_diverged").unwrap(), 0.0);
    let applied: f64 = (0..SHARDS)
        .map(|si| fc.stat(&format!("persist_next_seq_shard{si}")).unwrap())
        .sum();
    assert!(applied <= acked.len() as f64, "follower ahead of acked history");
    // the primary restarts on the SAME address (recovery from its WAL);
    // the follower's retry loop reconnects and finishes catch-up
    let primary = ServerProc::spawn_at(dir_p.path(), &primary_addr, &[]);
    assert_eq!(primary.addr, primary_addr, "primary must rebind its port");
    let mut pc = Client::connect(&primary.addr).expect("connect restarted primary");
    wait_parity(&mut pc, &mut fc);
    // every acked insert now answers identically on both processes
    let every = if soak { 31 } else { 1 };
    assert_serves_exactly(&mut pc, &acked, every);
    assert_serves_exactly(&mut fc, &acked, every);
    // and batched top-k is bit-identical primary vs replica
    let probes: Vec<CatVector> = acked
        .iter()
        .step_by(every * 3 + 1)
        .map(|(_, v)| v.clone())
        .collect();
    let from_primary = pc.query_batch(probes.clone(), 5).expect("primary query_batch");
    let from_follower = fc.query_batch(probes, 5).expect("follower query_batch");
    assert_eq!(from_primary, from_follower, "replica top-k diverged from primary");
    for (id, _) in acked.iter().step_by(13) {
        assert_eq!(pc.distance(*id, *id).unwrap(), fc.distance(*id, *id).unwrap());
    }
    let _ = fc.shutdown();
    let _ = pc.shutdown();
}
