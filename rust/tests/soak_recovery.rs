//! Crash-recovery soak: `kill -9` the real server binary mid-ingest and
//! verify that every *acknowledged* insert survives the restart.
//!
//! This is the durability contract end-to-end: the store WAL-commits each
//! batch before the batcher acknowledges it, so an insert whose response
//! reached the client must be recoverable — even though the process dies
//! with no teardown whatsoever. (With `--fsync always` the same holds
//! across power loss; a SIGKILL alone cannot lose OS-buffered writes, so
//! the test is deterministic either way.)
//!
//! One quick round runs in the tier-1 gate; the scheduled CI soak lane
//! sets `CABIN_SOAK=1` for more rounds with a larger corpus.

use cabin::coordinator::client::Client;
use cabin::data::CatVector;
use cabin::testing::TempDir;
use cabin::util::rng::Xoshiro256;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};

const DIM: usize = 400;

/// Kills the child on drop so a failing assert can't leak a server.
struct ServerProc {
    child: Child,
    pub addr: String,
}

impl ServerProc {
    fn spawn(data_dir: &std::path::Path, extra_args: &[&str]) -> ServerProc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_cabin"))
            .args([
                "serve",
                "--addr",
                "127.0.0.1:0",
                "--dim",
                "400",
                "--categories",
                "8",
                "--sketch-dim",
                "128",
                "--seed",
                "3",
                "--shards",
                "2",
                "--no-xla=true",
                "--max-delay-ms",
                "1",
                "--fsync",
                "always",
            ])
            .args(extra_args)
            .arg("--data-dir")
            .arg(data_dir)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn cabin serve");
        // `serve` prints "[serve] bound <addr>" once the listener is up
        let stdout = child.stdout.take().expect("child stdout");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            let line = lines
                .next()
                .expect("server exited before binding")
                .expect("read server stdout");
            if let Some(bound) = line.strip_prefix("[serve] bound ") {
                break bound.trim().to_string();
            }
        };
        // drain the rest of stdout in the background so the child can
        // never block on a full pipe
        std::thread::spawn(move || for _ in lines {});
        ServerProc { child, addr }
    }

    /// Hard stop: SIGKILL, no shutdown request, no flush.
    fn kill9(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        self.kill9();
    }
}

/// The durability contract, per commit mode: `window_us(round)` selects
/// the `--commit-window-us` each server life runs with, so the soak covers
/// both the synchronous per-batch commit path and group commit (where the
/// ack waits for the window's coalesced fsync — an acked insert must
/// survive `kill -9` identically in both).
fn soak_rounds(dir: &TempDir, rounds: usize, per_round: usize, window_us: &dyn Fn(usize) -> u64) {
    let mut rng = Xoshiro256::new(99);
    // (id, vector) pairs whose insert was acknowledged before a kill
    let mut acked: Vec<(usize, CatVector)> = Vec::new();

    for round in 0..rounds {
        let window = window_us(round).to_string();
        let mut server = ServerProc::spawn(dir.path(), &["--commit-window-us", window.as_str()]);
        let mut c = Client::connect(&server.addr).expect("connect");
        // every previously-acked insert must already be back
        for (id, v) in &acked {
            let hits = c.query(v.clone(), 1).expect("query recovered corpus");
            assert_eq!(hits[0].id, *id, "round {round}: id {id} lost after kill -9");
            assert!(
                hits[0].dist < 1e-9,
                "round {round}: id {id} corrupted (dist {})",
                hits[0].dist
            );
        }
        // ingest this round's batch; record each ack
        for _ in 0..per_round {
            let v = CatVector::random(DIM, 50, 8, &mut rng);
            let id = c.insert(v.clone()).expect("insert");
            acked.push((id, v));
        }
        // mid-stream hard stop: some queued-but-unacked work may exist in
        // the batcher; acked work must survive regardless
        server.kill9();
    }

    // final life: everything ever acknowledged is present and exact
    let final_window = window_us(rounds).to_string();
    let mut server =
        ServerProc::spawn(dir.path(), &["--commit-window-us", final_window.as_str()]);
    let mut c = Client::connect(&server.addr).expect("connect final");
    assert_eq!(acked.len(), rounds * per_round);
    for (id, v) in &acked {
        let hits = c.query(v.clone(), 1).expect("query final corpus");
        assert_eq!(hits[0].id, *id, "id {id} lost in final recovery");
        assert!(hits[0].dist < 1e-9);
        assert_eq!(c.distance(*id, *id).unwrap(), 0.0);
    }
    assert_eq!(c.stat("persist_cfg_mode").unwrap(), 2.0);
    assert_eq!(
        c.stat("persist_cfg_commit_window_us").unwrap(),
        window_us(rounds) as f64
    );
    let _ = c.shutdown();
    let _ = server.child.wait();
}

#[test]
fn kill9_mid_ingest_then_restart_recovers_every_acked_insert() {
    let soak = std::env::var("CABIN_SOAK").ok().as_deref() == Some("1");
    let (rounds, per_round) = if soak { (4, 120) } else { (1, 40) };
    let dir = TempDir::new("soak-recovery");
    // synchronous per-batch commits: the pre-group-commit contract
    soak_rounds(&dir, rounds, per_round, &|_round| 0);
}

#[test]
fn kill9_with_group_commit_recovers_every_acked_insert() {
    let soak = std::env::var("CABIN_SOAK").ok().as_deref() == Some("1");
    let (rounds, per_round) = if soak { (4, 120) } else { (1, 40) };
    let dir = TempDir::new("soak-recovery-group");
    // alternate window sizes across lives: the recovered corpus must be
    // indifferent to the commit mode that wrote (or re-reads) it
    soak_rounds(&dir, rounds, per_round, &|round| {
        if round % 2 == 0 {
            2_000
        } else {
            500
        }
    });
}
