#!/usr/bin/env python3
"""Bench trajectory tooling for CI.

Two subcommands, stdlib only:

  report  — collect the CSVs the cabin bench harness writes to
            rust/results/bench_<suite>.csv into one machine-readable
            BENCH_*.json (per-bench suite, name, corpus size, wall-ms,
            throughput).

  check   — compare a PR's BENCH_pr.json against the committed
            BENCH_baseline.json and fail (exit 1) on regressions beyond
            --max-regression (default 25%) on p50 wall time. Every
            failure line names the suite and bench and labels both p50s
            (baseline_p50_ms / current_p50_ms) so CI logs are
            self-describing. --emit-md PATH additionally writes the full
            comparison as a markdown table (for PR comments / job
            summaries). A baseline marked "provisional": true (or with
            no benches) records the trajectory without gating, and
            prints the JSON to commit as the real baseline.

Wall times are compared on p50, not mean, to damp CI runner noise.
"""

import argparse
import csv
import json
import re
import sys
from pathlib import Path

SCHEMA = 1


def parse_corpus(name: str) -> int:
    """Best-effort corpus size from a bench name.

    Bench names embed their scale as 'corpus1000', a path segment like
    '/20000' or '/20000x1024', or a trailing '/100k'.
    """
    m = re.search(r"corpus(\d+)", name)
    if m:
        return int(m.group(1))
    m = re.search(r"/(\d+)k(?:/|$)", name)
    if m:
        return int(m.group(1)) * 1000
    m = re.search(r"/(\d+)(?:x\d+)?(?:/|$)", name)
    if m:
        return int(m.group(1))
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    csv_dir = Path(args.csv_dir)
    benches = []
    for path in sorted(csv_dir.glob("bench_*.csv")):
        suite = path.stem.removeprefix("bench_")
        with path.open(newline="") as fh:
            for row in csv.DictReader(fh):
                wall_ms = float(row["p50_s"]) * 1e3
                thrpt = row.get("thrpt_per_s", "")
                benches.append(
                    {
                        "suite": suite,
                        "name": row["name"],
                        "corpus": parse_corpus(row["name"]),
                        "iters": int(row["iters"]),
                        "wall_ms": round(wall_ms, 4),
                        "mean_ms": round(float(row["mean_s"]) * 1e3, 4),
                        "throughput_per_s": float(thrpt) if thrpt else None,
                    }
                )
    if not benches:
        print(f"error: no bench_*.csv files under {csv_dir}", file=sys.stderr)
        return 1
    doc = {"schema": SCHEMA, "provisional": False, "benches": benches}
    out = Path(args.out)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"[bench_gate] wrote {out} ({len(benches)} benches)")
    return 0


def load(path: str) -> dict:
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("schema") != SCHEMA:
        raise SystemExit(f"{path}: unsupported schema {doc.get('schema')!r}")
    return doc


def cmd_check(args: argparse.Namespace) -> int:
    current = load(args.current)
    baseline = load(args.baseline)
    cur = {(b["suite"], b["name"]): b for b in current["benches"]}
    base = {(b["suite"], b["name"]): b for b in baseline["benches"]}

    if baseline.get("provisional") or not base:
        print(
            "[bench_gate] baseline is provisional/empty — recording the "
            "trajectory without gating. To arm the regression gate, commit "
            f"{args.current} as {args.baseline} from a trusted run."
        )
        width = max((len(f"{s}/{n}") for s, n in cur), default=0)
        for (suite, name), b in sorted(cur.items()):
            print(f"  {f'{suite}/{name}':<{width}}  {b['wall_ms']:>10.3f} ms")
        return 0

    failures = []
    rows = []  # (status, suite, name, baseline_ms | None, current_ms | None, ratio | None)
    print(f"[bench_gate] comparing {len(cur)} benches against {len(base)} baseline entries")
    for key in sorted(cur):
        suite, name = key
        suite_name = "/".join(key)
        if key not in base:
            print(f"  NEW      {suite_name} ({cur[key]['wall_ms']:.3f} ms, no baseline)")
            rows.append(("new", suite, name, None, cur[key]["wall_ms"], None))
            continue
        b, c = base[key]["wall_ms"], cur[key]["wall_ms"]
        ratio = c / b if b > 0 else float("inf")
        status = "ok"
        if ratio > 1 + args.max_regression:
            status = "REGRESSED"
            failures.append((suite, name, b, c, ratio))
        print(f"  {status:<8} {suite_name}  {b:.3f} -> {c:.3f} ms  ({ratio - 1:+.1%})")
        rows.append((status.lower(), suite, name, b, c, ratio))
    for key in sorted(set(base) - set(cur)):
        print(f"  MISSING  {'/'.join(key)} (in baseline, not in this run)")
        rows.append(("missing", key[0], key[1], base[key]["wall_ms"], None, None))

    if args.emit_md:
        emit_md(args.emit_md, rows, args.max_regression)

    if failures:
        print(
            f"\n[bench_gate] FAIL: {len(failures)} bench(es) regressed more than "
            f"{args.max_regression:.0%} vs {args.baseline}:",
            file=sys.stderr,
        )
        for suite, name, b, c, ratio in failures:
            print(
                f"  suite={suite} bench={name} "
                f"baseline_p50_ms={b:.3f} current_p50_ms={c:.3f} ({ratio:.2f}x)",
                file=sys.stderr,
            )
        return 1
    print("[bench_gate] OK: no regressions beyond the threshold")
    return 0


def emit_md(path: str, rows: list, max_regression: float) -> None:
    """Write the comparison as a markdown table (PR comment / job summary)."""
    def ms(v):
        return f"{v:.3f}" if v is not None else "—"

    def delta(r):
        return f"{r - 1:+.1%}" if r is not None else "—"

    badge = {"ok": "✅", "regressed": "❌", "new": "🆕", "missing": "⚠️"}
    lines = [
        f"### Bench gate (p50 wall time, threshold {max_regression:.0%})",
        "",
        "| status | suite | bench | baseline p50 (ms) | current p50 (ms) | delta |",
        "|---|---|---|---:|---:|---:|",
    ]
    for status, suite, name, b, c, ratio in rows:
        lines.append(
            f"| {badge.get(status, status)} {status} | {suite} | `{name}` "
            f"| {ms(b)} | {ms(c)} | {delta(ratio)} |"
        )
    Path(path).write_text("\n".join(lines) + "\n")
    print(f"[bench_gate] wrote markdown summary to {path}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="CSV dir -> BENCH json")
    rep.add_argument("--csv-dir", default="rust/results")
    rep.add_argument("--out", default="BENCH_pr.json")
    rep.set_defaults(fn=cmd_report)
    chk = sub.add_parser("check", help="gate a BENCH json against the baseline")
    chk.add_argument("--current", default="BENCH_pr.json")
    chk.add_argument("--baseline", default="BENCH_baseline.json")
    chk.add_argument("--max-regression", type=float, default=0.25)
    chk.add_argument(
        "--emit-md",
        default=None,
        metavar="PATH",
        help="also write the comparison as a markdown table",
    )
    chk.set_defaults(fn=cmd_check)
    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
