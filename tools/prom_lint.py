#!/usr/bin/env python3
"""Validate Prometheus text exposition scraped from `cabin-sketch serve`.

Stdlib only. Usage:

  python3 tools/prom_lint.py primary.txt [follower.txt ...]

Checks, per file:

  * every sample name matches the metric-name grammar
    ``[a-zA-Z_:][a-zA-Z0-9_:]*``;
  * every sample family has exactly one ``# TYPE`` line, and it appears
    before the family's first sample (``x_bucket`` / ``x_sum`` /
    ``x_count`` samples belong to the base family ``x`` when ``x`` is
    declared a histogram);
  * counter sample names end in ``_total``;
  * label blocks parse in full under the label grammar
    ``name="value"(,name="value")*`` (label names ``[a-zA-Z_][a-zA-Z0-9_]*``,
    values with ``\\``-escapes), label names within one sample are unique
    and sorted, and no two samples share the same (name, labelset) —
    the labeled-family invariants behind ``cabin_repl_lag{shard="3"}``;
  * histogram families expose ``_bucket`` samples with non-decreasing
    cumulative counts in ``le`` order, include an ``le="+Inf"`` bucket,
    and that bucket equals the family's ``_count``; ``_sum`` and
    ``_count`` must both be present;
  * no metric name is emitted under two different types.

Exit 0 when every file passes, 1 otherwise; one diagnostic line per
violation (``file:line: message``).
"""

import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)\s*$"
)
TYPE_RE = re.compile(r"^# TYPE (?P<name>\S+) (?P<kind>\S+)\s*$")
LE_RE = re.compile(r'le="(?P<le>[^"]+)"')
# One label pair; values may contain backslash escapes (\" \\ \n).
LABEL_PAIR_RE = re.compile(r'(?P<name>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<value>(?:[^"\\]|\\.)*)"')
LABELS_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*,?$'
)


def parse_labels(raw):
    """Parse a label block body into ordered (name, value) pairs.

    Returns None when the block does not full-match the label grammar —
    a partial regex hit (e.g. a malformed pair hiding between valid
    ones) must fail the sample, not silently drop labels.
    """
    if raw is None or raw == "":
        return []
    if not LABELS_RE.match(raw):
        return None
    return [(m.group("name"), m.group("value")) for m in LABEL_PAIR_RE.finditer(raw)]


def parse_le(raw):
    if raw == "+Inf":
        return float("inf")
    try:
        return float(raw)
    except ValueError:
        return None


def base_family(name, types):
    """Map a histogram-series sample name back to its declared family."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            base = name[: -len(suffix)]
            if types.get(base) == "histogram":
                return base
    return name


def lint_file(path):
    errors = []

    def err(lineno, msg):
        errors.append(f"{path}:{lineno}: {msg}")

    try:
        with open(path, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except OSError as exc:
        return [f"{path}: unreadable: {exc}"]

    if not any(ln.strip() and not ln.startswith("#") for ln in lines):
        return [f"{path}: no samples found"]

    # Pass 1: collect TYPE declarations (needed to resolve histogram
    # series names in pass 2 regardless of declaration order).
    types = {}
    for lineno, line in enumerate(lines, 1):
        m = TYPE_RE.match(line)
        if not m:
            continue
        name, kind = m.group("name"), m.group("kind")
        if not NAME_RE.match(name):
            err(lineno, f"bad metric name in TYPE line: {name!r}")
        if kind not in ("counter", "gauge", "histogram", "summary", "untyped"):
            err(lineno, f"unknown type {kind!r} for {name}")
        if name in types:
            err(lineno, f"duplicate # TYPE for {name}")
        else:
            types[name] = kind

    # Pass 2: walk samples in order.
    type_seen_at = {}      # family -> lineno of its TYPE line
    first_sample_at = {}   # family -> lineno of its first sample
    buckets = {}           # family -> list of (le, value, lineno)
    sums = {}              # family -> value
    counts = {}            # family -> (value, lineno)
    seen_series = {}       # (name, labelset) -> lineno of first sample
    for lineno, line in enumerate(lines, 1):
        if not line.strip():
            continue
        tm = TYPE_RE.match(line)
        if tm:
            type_seen_at.setdefault(tm.group("name"), lineno)
            continue
        if line.startswith("#"):
            continue  # HELP or comment
        m = SAMPLE_RE.match(line)
        if not m:
            err(lineno, f"unparseable sample line: {line!r}")
            continue
        name = m.group("name")
        if not NAME_RE.match(name):
            err(lineno, f"bad metric name: {name!r}")
            continue
        try:
            value = float(m.group("value"))
        except ValueError:
            err(lineno, f"bad sample value {m.group('value')!r} for {name}")
            continue
        labels = parse_labels(m.group("labels"))
        if labels is None:
            err(lineno, f"bad label block on {name}: {m.group('labels')!r}")
            continue
        label_names = [ln for ln, _ in labels]
        if len(set(label_names)) != len(label_names):
            err(lineno, f"duplicate label name on {name}: {label_names}")
        elif label_names != sorted(label_names):
            err(lineno, f"label names on {name} not sorted: {label_names}")
        series_key = (name, tuple(sorted(labels)))
        dup = seen_series.setdefault(series_key, lineno)
        if dup != lineno:
            err(lineno, f"duplicate series {name}{dict(labels)} "
                        f"(first at line {dup})")
        family = base_family(name, types)
        first_sample_at.setdefault(family, lineno)
        kind = types.get(family)
        if kind is None:
            err(lineno, f"sample {name} has no # TYPE line for family {family}")
            continue
        if kind == "counter" and not name.endswith("_total"):
            err(lineno, f"counter sample {name} does not end in _total")
        if kind == "histogram":
            if name.endswith("_bucket"):
                lm = LE_RE.search(m.group("labels") or "")
                if not lm:
                    err(lineno, f"histogram bucket {name} missing le label")
                    continue
                le = parse_le(lm.group("le"))
                if le is None:
                    err(lineno, f"unparseable le={lm.group('le')!r} on {name}")
                    continue
                buckets.setdefault(family, []).append((le, value, lineno))
            elif name.endswith("_sum"):
                sums[family] = value
            elif name.endswith("_count"):
                counts[family] = (value, lineno)
            else:
                err(lineno, f"histogram family {family} has stray sample {name}")

    for family, lineno in first_sample_at.items():
        declared = type_seen_at.get(family)
        if declared is not None and declared > lineno:
            err(lineno, f"# TYPE for {family} appears after its first sample")

    for family, kind in types.items():
        if kind != "histogram":
            continue
        series = buckets.get(family, [])
        if not series:
            err(type_seen_at.get(family, 0), f"histogram {family} has no _bucket samples")
            continue
        prev = None
        for le, value, lineno in series:  # exposition order, as rendered
            if prev is not None and value < prev:
                err(lineno, f"histogram {family} bucket le={le} count {value} "
                            f"decreases from previous bucket {prev}")
            prev = value
        les = [le for le, _, _ in series]
        if les != sorted(les):
            err(series[0][2], f"histogram {family} buckets not in ascending le order")
        if not any(le == float("inf") for le in les):
            err(series[-1][2], f"histogram {family} missing le=\"+Inf\" bucket")
        if family not in sums:
            err(series[0][2], f"histogram {family} missing _sum sample")
        if family not in counts:
            err(series[0][2], f"histogram {family} missing _count sample")
        else:
            count, clineno = counts[family]
            inf = [v for le, v, _ in series if le == float("inf")]
            if inf and inf[0] != count:
                err(clineno, f"histogram {family} le=\"+Inf\" bucket {inf[0]} "
                             f"!= _count {count}")
    return errors


def main(argv):
    if len(argv) < 2 or argv[1] in ("-h", "--help"):
        print(__doc__.strip())
        return 0 if len(argv) >= 2 else 1
    failed = False
    for path in argv[1:]:
        errors = lint_file(path)
        if errors:
            failed = True
            for e in errors:
                print(e)
        else:
            print(f"{path}: OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
