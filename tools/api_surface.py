#!/usr/bin/env python3
"""Wire-surface compat gate for CI.

The committed fixtures under protocol-fixtures/ are the byte-level
contract for the coordinator's wire protocol (replayed by
rust/tests/protocol_compat.rs). A change to them IS a wire-surface
change, so it must land with its compat story written down in
docs/PROTOCOL.md. Two subcommands, stdlib only:

  hash    — print the sha256 of the fixture set (sorted relative path +
            file bytes), the identity the gate compares. Useful locally
            to see whether a working tree touches the surface.

  check   — given --base/--head git refs, fail (exit 1) when the diff
            touches protocol-fixtures/ without touching
            docs/PROTOCOL.md. An unresolvable base (first push to a
            branch, shallow clone) degrades to "everything changed",
            which passes iff the docs changed too — the conservative
            reading.

The gate is direction-agnostic on purpose: adding, editing or deleting
a fixture all count. It does not try to judge the *content* of the doc
change — review does that — only that one exists in the same range.
"""

import argparse
import hashlib
import subprocess
import sys
from pathlib import Path

FIXTURE_DIR = "protocol-fixtures"
PROTOCOL_DOC = "docs/PROTOCOL.md"


def repo_root() -> Path:
    out = subprocess.run(
        ["git", "rev-parse", "--show-toplevel"],
        capture_output=True,
        text=True,
        check=True,
    )
    return Path(out.stdout.strip())


def fixture_hash(root: Path) -> str:
    h = hashlib.sha256()
    fdir = root / FIXTURE_DIR
    for path in sorted(fdir.rglob("*")):
        if not path.is_file():
            continue
        h.update(str(path.relative_to(root)).encode())
        h.update(b"\0")
        h.update(path.read_bytes())
        h.update(b"\0")
    return h.hexdigest()


def resolve(ref: str) -> str | None:
    out = subprocess.run(
        ["git", "rev-parse", "--verify", "--quiet", f"{ref}^{{commit}}"],
        capture_output=True,
        text=True,
    )
    return out.stdout.strip() if out.returncode == 0 else None


def changed_files(base: str, head: str) -> list[str]:
    out = subprocess.run(
        ["git", "diff", "--name-only", f"{base}...{head}"],
        capture_output=True,
        text=True,
        check=True,
    )
    return [line for line in out.stdout.splitlines() if line]


def cmd_hash(_args: argparse.Namespace) -> int:
    print(fixture_hash(repo_root()))
    return 0


def cmd_check(args: argparse.Namespace) -> int:
    root = repo_root()
    print(f"fixture surface hash: {fixture_hash(root)}")
    base = resolve(args.base)
    head = resolve(args.head)
    if head is None:
        print(f"cannot resolve head ref {args.head!r}", file=sys.stderr)
        return 1
    if base is None:
        # e.g. github.event.before on a branch-creation push is the zero
        # oid — treat every tracked file as changed
        print(f"base ref {args.base!r} does not resolve; treating all files as changed")
        out = subprocess.run(
            ["git", "ls-tree", "-r", "--name-only", head],
            capture_output=True,
            text=True,
            check=True,
        )
        changed = [line for line in out.stdout.splitlines() if line]
    else:
        changed = changed_files(base, head)

    fixtures = sorted(p for p in changed if p.startswith(FIXTURE_DIR + "/"))
    doc_changed = PROTOCOL_DOC in changed
    if not fixtures:
        print("wire-surface fixtures untouched — gate passes")
        return 0
    print("wire-surface fixtures changed:")
    for p in fixtures:
        print(f"  {p}")
    if doc_changed:
        print(f"{PROTOCOL_DOC} changed in the same range — gate passes")
        return 0
    print(
        f"FAIL: {FIXTURE_DIR}/ changed without {PROTOCOL_DOC}.\n"
        "A fixture change is a wire-surface change: update the protocol\n"
        "document (op tables, framing, deprecation window) in the same\n"
        "commit so the compat story ships with the change.",
        file=sys.stderr,
    )
    return 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("hash", help="print the fixture-set sha256")
    chk = sub.add_parser("check", help="gate a git range")
    chk.add_argument("--base", required=True, help="base ref of the range")
    chk.add_argument("--head", default="HEAD", help="head ref (default HEAD)")
    args = ap.parse_args()
    return {"hash": cmd_hash, "check": cmd_check}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
